//! Streaming ingest: train continuously over a corpus that is still
//! being written.
//!
//! The paper's trainer (and every batch word2vec) assumes the corpus is
//! finished before training starts.  This subsystem removes that
//! assumption without forking the training pipeline: the stream driver
//! feeds the SAME subsample → window-generation → superbatch → fused
//! GEMM kernel path as `train`, reading lines through a persistent
//! [`TailReader`] instead of a fixed-range `SentenceReader`.
//!
//! Layout:
//!
//! * [`tail`] — file tailer (partial-line push-back) and the
//!   `--follow tcp:` ingest feed that turns a socket into file appends;
//! * [`driver`] — [`StreamTrainer`]: the batch worker loop replayed
//!   line-at-a-time, plus vocabulary admission into `--vocab-reserve`
//!   rows, learning-rate horizon growth, lazy encoded-cache
//!   maintenance, and serve-store export;
//! * [`ckpt`] — the `.stream` sidecar that rides next to the PR-6
//!   two-slot `PWCK` model checkpoint so a killed streamer warm-restarts
//!   bitwise (`--resume`).
//!
//! Guarantees (pinned by `tests/stream_parity.rs`): a stream over a
//! never-growing file is bitwise identical to the batch run on the same
//! bytes, and kill + resume is bitwise identical to an uninterrupted
//! stream.

pub mod ckpt;
pub mod driver;
pub mod tail;

pub use driver::{StreamOptions, StreamOutcome, StreamTrainer};
pub use tail::TailReader;
