//! The `.stream` sidecar: everything a killed streamer needs to resume
//! that the PR-6 `PWCK` model checkpoint does not carry.
//!
//! A streaming checkpoint is two files written in a fixed order:
//!
//! 1. the model snapshot, via the existing two-slot `PWCK` machinery
//!    (`model/io.rs`) — slot `round % 2`, so a crash mid-write can only
//!    corrupt the slot being replaced;
//! 2. this sidecar (atomic rename), which records the stream cursor,
//!    the grown learning-rate horizon, the encoded-cache watermark and
//!    the LIVE vocabulary (admissions included) plus pending admission
//!    candidates.
//!
//! Because the sidecar lands last, a loaded sidecar always references a
//! fully-written `PWCK` slot; `round` ties the two together and the
//! `PWCK` fingerprint (config ^ vocab ^ nranks) cross-checks that the
//! restored vocabulary is the one the model rows were trained against.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::model::io::atomic_write;
use crate::util::fnv::Fnv1a;

const MAGIC: [u8; 8] = *b"PWSTRM\0\0";
const VERSION: u16 = 1;
/// Sanity cap on serialized token length (bytes).
const MAX_TOKEN_LEN: u32 = 1 << 20;

/// Stream-cursor state saved alongside a `PWCK` model checkpoint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamState {
    /// Checkpoint sequence number (+1 per checkpoint, NOT per flush —
    /// an even `ckpt_every` would otherwise pin one slot forever);
    /// selects `PWCK` slot `round % 2`.
    pub round: u64,
    /// Byte offset of the next unread line start in the corpus.
    pub pos: u64,
    /// Bytes whose word counts are already in the lr horizon.
    pub observed_end: u64,
    /// Vocabulary length at cold start — the prefix whose subsampling
    /// probabilities were computed from the original counts.  Resume
    /// rebuilds the subsampler from `vocab.truncated(base_len)` and
    /// extends with keep-probability 1.0 for admitted rows, exactly
    /// reproducing the running streamer's table (a plain rebuild over
    /// the grown vocab would perturb every prefix probability through
    /// the larger total `T`).
    pub base_len: u64,
    /// Learning-rate horizon (`LrState::total`), grown by every
    /// observed suffix.
    pub lr_total: u64,
    /// Corpus bytes the on-disk encoded cache covers (0 = no cache
    /// written yet).
    pub cache_end: u64,
    /// Vocab fingerprint the encoded cache was built under.
    pub cache_fp: u64,
    /// Vocab admission generation.
    pub generation: u64,
    /// Live vocabulary in id order.
    pub words: Vec<String>,
    pub counts: Vec<u64>,
    /// Pending admission candidates (word, observed count).
    pub candidates: Vec<(String, u64)>,
}

/// `<base>.stream` next to the `PWCK` slots.
pub fn sidecar_path(base: &Path) -> PathBuf {
    let mut os = base.as_os_str().to_os_string();
    os.push(".stream");
    PathBuf::from(os)
}

fn put(w: &mut impl Write, h: &mut Fnv1a, bytes: &[u8]) -> anyhow::Result<()> {
    h.update(bytes);
    w.write_all(bytes)?;
    Ok(())
}

fn put_str(w: &mut impl Write, h: &mut Fnv1a, s: &str) -> anyhow::Result<()> {
    anyhow::ensure!(
        s.len() <= MAX_TOKEN_LEN as usize,
        "stream sidecar: token of {} bytes exceeds the {} cap",
        s.len(),
        MAX_TOKEN_LEN
    );
    put(w, h, &(s.len() as u32).to_le_bytes())?;
    put(w, h, s.as_bytes())
}

fn take<const N: usize>(r: &mut impl Read, h: &mut Fnv1a) -> anyhow::Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    h.update(&buf);
    Ok(buf)
}

fn take_u64(r: &mut impl Read, h: &mut Fnv1a) -> anyhow::Result<u64> {
    Ok(u64::from_le_bytes(take::<8>(r, h)?))
}

fn take_str(r: &mut impl Read, h: &mut Fnv1a) -> anyhow::Result<String> {
    let len = u32::from_le_bytes(take::<4>(r, h)?);
    anyhow::ensure!(
        len <= MAX_TOKEN_LEN,
        "stream sidecar: token length {len} exceeds the {MAX_TOKEN_LEN} cap (corrupt?)"
    );
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    h.update(&buf);
    String::from_utf8(buf).map_err(|_| anyhow::anyhow!("stream sidecar: non-UTF-8 token"))
}

/// Write the sidecar atomically (`.tmp` + fsync + rename), FNV-1a
/// trailer last.
pub fn save_state(base: &Path, st: &StreamState) -> anyhow::Result<()> {
    anyhow::ensure!(
        st.words.len() == st.counts.len(),
        "stream sidecar: {} words vs {} counts",
        st.words.len(),
        st.counts.len()
    );
    atomic_write(sidecar_path(base), |w| {
        let mut h = Fnv1a::new();
        put(w, &mut h, &MAGIC)?;
        put(w, &mut h, &VERSION.to_le_bytes())?;
        for v in [
            st.round,
            st.pos,
            st.observed_end,
            st.base_len,
            st.lr_total,
            st.cache_end,
            st.cache_fp,
            st.generation,
            st.words.len() as u64,
        ] {
            put(w, &mut h, &v.to_le_bytes())?;
        }
        for (word, count) in st.words.iter().zip(&st.counts) {
            put_str(w, &mut h, word)?;
            put(w, &mut h, &count.to_le_bytes())?;
        }
        put(w, &mut h, &(st.candidates.len() as u64).to_le_bytes())?;
        for (word, count) in &st.candidates {
            put_str(w, &mut h, word)?;
            put(w, &mut h, &count.to_le_bytes())?;
        }
        w.write_all(&h.digest().to_le_bytes())?;
        Ok(())
    })
}

/// Load and verify `<base>.stream`.
pub fn load_state(base: &Path) -> anyhow::Result<StreamState> {
    let path = sidecar_path(base);
    let mut r = std::io::BufReader::new(std::fs::File::open(&path)?);
    let mut h = Fnv1a::new();
    let magic = take::<8>(&mut r, &mut h)?;
    anyhow::ensure!(
        magic == MAGIC,
        "{}: not a stream sidecar (bad magic)",
        path.display()
    );
    let version = u16::from_le_bytes(take::<2>(&mut r, &mut h)?);
    anyhow::ensure!(
        version == VERSION,
        "{}: sidecar version {version}, this build reads {VERSION}",
        path.display()
    );
    let round = take_u64(&mut r, &mut h)?;
    let pos = take_u64(&mut r, &mut h)?;
    let observed_end = take_u64(&mut r, &mut h)?;
    let base_len = take_u64(&mut r, &mut h)?;
    let lr_total = take_u64(&mut r, &mut h)?;
    let cache_end = take_u64(&mut r, &mut h)?;
    let cache_fp = take_u64(&mut r, &mut h)?;
    let generation = take_u64(&mut r, &mut h)?;
    let n_words = take_u64(&mut r, &mut h)?;
    let mut words = Vec::with_capacity(n_words.min(1 << 24) as usize);
    let mut counts = Vec::with_capacity(words.capacity());
    for _ in 0..n_words {
        words.push(take_str(&mut r, &mut h)?);
        counts.push(take_u64(&mut r, &mut h)?);
    }
    let n_cand = take_u64(&mut r, &mut h)?;
    let mut candidates = Vec::with_capacity(n_cand.min(1 << 24) as usize);
    for _ in 0..n_cand {
        let w = take_str(&mut r, &mut h)?;
        candidates.push((w, take_u64(&mut r, &mut h)?));
    }
    let want = h.digest();
    let mut trailer = [0u8; 8];
    r.read_exact(&mut trailer)?;
    anyhow::ensure!(
        u64::from_le_bytes(trailer) == want,
        "{}: sidecar checksum mismatch (truncated or corrupt)",
        path.display()
    );
    Ok(StreamState {
        round,
        pos,
        observed_end,
        base_len,
        lr_total,
        cache_end,
        cache_fp,
        generation,
        words,
        counts,
        candidates,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StreamState {
        StreamState {
            round: 7,
            pos: 4096,
            observed_end: 5000,
            base_len: 3,
            lr_total: 123_456,
            cache_end: 2048,
            cache_fp: 0xDEAD_BEEF,
            generation: 2,
            words: vec!["the".into(), "quick".into(), "fox".into(), "nova".into()],
            counts: vec![100, 40, 17, 5],
            candidates: vec![("comet".into(), 3), ("quasar".into(), 1)],
        }
    }

    fn base(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("pw2v_sidecar_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let b = base("roundtrip");
        let st = sample();
        save_state(&b, &st).unwrap();
        assert_eq!(load_state(&b).unwrap(), st);
        std::fs::remove_file(sidecar_path(&b)).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let b = base("corrupt");
        save_state(&b, &sample()).unwrap();
        let p = sidecar_path(&b);
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = load_state(&b).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("cap") || err.contains("token"),
            "unexpected error: {err}"
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncation_is_detected() {
        let b = base("trunc");
        save_state(&b, &sample()).unwrap();
        let p = sidecar_path(&b);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        assert!(load_state(&b).is_err());
        std::fs::remove_file(&p).ok();
    }
}
