//! Corpus tailing primitives.
//!
//! * [`TailReader`] — a persistent buffered reader over a growing text
//!   file.  Unlike `SentenceReader` (which owns a fixed `[start, end)`
//!   range), the tailer keeps its `BufReader` open across polls and
//!   hands out one complete `\n`-terminated line at a time, pushing a
//!   partial trailing line back (the writer has not finished it yet) so
//!   the stream of consumed lines is independent of poll timing.  That
//!   independence is what makes streaming training reproducible: the
//!   sentence sequence fed to the trainer is a pure function of the
//!   final file bytes, never of when we looked.
//! * [`follow_listener`] / [`pump_tcp`] — the `--follow tcp:<addr>`
//!   ingest feed: a listener thread accepts line-oriented socket
//!   connections and appends complete lines to the corpus file, turning
//!   the socket feed into the same grew-by-suffix file the tailer reads.

use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// Persistent tail over a growing text file.
pub struct TailReader {
    reader: BufReader<File>,
    /// Byte offset of the next unread line start.
    pos: u64,
}

impl TailReader {
    /// Open `path` positioned at byte `from` (must be a line start —
    /// offset 0 or the byte after a `\n`).  Seeking past the current
    /// EOF is fine: reads return nothing until the file grows.
    pub fn open(path: &Path, from: u64) -> anyhow::Result<Self> {
        let mut f = File::open(path)?;
        f.seek(SeekFrom::Start(from))?;
        Ok(Self {
            reader: BufReader::with_capacity(1 << 20, f),
            pos: from,
        })
    }

    /// Byte offset of the next unread line start.
    pub fn pos(&self) -> u64 {
        self.pos
    }

    /// Read the next complete line (without its `\n`) into `out`,
    /// returning its `(start, end)` byte span — `end` is the offset just
    /// past the terminator, i.e. the next line start.  Returns `None`
    /// when the cursor has reached `limit`, when the file has no more
    /// bytes, or when only a PARTIAL line is available: the partial tail
    /// is pushed back (the reader rewinds) and will be retried on the
    /// next call, by which time the writer may have finished it.
    ///
    /// `out` is caller-owned so the steady-state loop reuses one
    /// allocation forever.
    pub fn next_line_into(
        &mut self,
        limit: u64,
        out: &mut String,
    ) -> anyhow::Result<Option<(u64, u64)>> {
        if self.pos >= limit {
            return Ok(None);
        }
        out.clear();
        let n = self.reader.read_line(out)?;
        if n == 0 {
            return Ok(None);
        }
        if !out.ends_with('\n') {
            // Partial tail: the writer is mid-line.  Push it back and
            // wait; consuming it now would split one sentence in two
            // and make training depend on poll timing.
            self.reader.seek_relative(-(n as i64))?;
            out.clear();
            return Ok(None);
        }
        let start = self.pos;
        self.pos += n as u64;
        out.truncate(out.trim_end_matches(['\n', '\r']).len());
        Ok(Some((start, self.pos)))
    }
}

/// Parse a `--follow` spec; only `tcp:<addr>` is understood.
pub fn parse_follow(spec: &str) -> anyhow::Result<&str> {
    match spec.strip_prefix("tcp:") {
        Some(addr) if !addr.is_empty() => Ok(addr),
        _ => anyhow::bail!("stream: --follow expects tcp:HOST:PORT, got '{spec}'"),
    }
}

/// Bind the ingest listener up front so an unusable address fails the
/// run immediately instead of surfacing at thread-join time.
pub fn follow_listener(addr: &str) -> anyhow::Result<TcpListener> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("stream: cannot listen on {addr}: {e}"))?;
    listener.set_nonblocking(true)?;
    Ok(listener)
}

/// Accept connections sequentially and append their complete
/// `\n`-terminated lines to `corpus`; a dangling partial line at
/// connection close is completed with a `\n` (the sender hung up
/// mid-line — dropping the words would silently lose data).  Returns
/// the number of bytes appended.  Checks `stop` between reads.
pub fn pump_tcp(listener: &TcpListener, corpus: &Path, stop: &AtomicBool) -> anyhow::Result<u64> {
    let mut sink = OpenOptions::new().append(true).create(true).open(corpus)?;
    let mut appended = 0u64;
    let mut carry: Vec<u8> = Vec::new();
    let mut buf = [0u8; 64 * 1024];
    'accept: while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The accepted socket may inherit the listener's
                // nonblocking flag on some platforms; force blocking
                // with a timeout so the stop flag stays responsive.
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(Some(Duration::from_millis(100)))?;
                let mut stream = stream;
                loop {
                    if stop.load(Ordering::Acquire) {
                        break 'accept;
                    }
                    match stream.read(&mut buf) {
                        Ok(0) => break,
                        Ok(n) => {
                            carry.extend_from_slice(&buf[..n]);
                            if let Some(cut) = carry.iter().rposition(|&b| b == b'\n') {
                                sink.write_all(&carry[..=cut])?;
                                sink.flush()?;
                                appended += (cut + 1) as u64;
                                carry.drain(..=cut);
                            }
                        }
                        Err(e)
                            if e.kind() == std::io::ErrorKind::WouldBlock
                                || e.kind() == std::io::ErrorKind::TimedOut =>
                        {
                            continue;
                        }
                        Err(e) => return Err(e.into()),
                    }
                }
                if !carry.is_empty() {
                    sink.write_all(&carry)?;
                    sink.write_all(b"\n")?;
                    sink.flush()?;
                    appended += carry.len() as u64 + 1;
                    carry.clear();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(appended)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tmp(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("pw2v_tail_{name}_{}", std::process::id()));
        std::fs::write(&p, contents).unwrap();
        p
    }

    #[test]
    fn yields_complete_lines_and_pushes_back_partials() {
        let p = tmp("partial", b"alpha beta\ngamma");
        let mut t = TailReader::open(&p, 0).unwrap();
        let mut line = String::new();
        let span = t.next_line_into(u64::MAX, &mut line).unwrap();
        assert_eq!(span, Some((0, 11)));
        assert_eq!(line, "alpha beta");
        // "gamma" has no terminator yet: pushed back, not consumed.
        assert_eq!(t.next_line_into(u64::MAX, &mut line).unwrap(), None);
        assert_eq!(t.pos(), 11);
        // Writer finishes the line and adds another.
        let mut f = OpenOptions::new().append(true).open(&p).unwrap();
        f.write_all(b" delta\nepsilon\n").unwrap();
        drop(f);
        let span = t.next_line_into(u64::MAX, &mut line).unwrap();
        assert_eq!(span, Some((11, 23)));
        assert_eq!(line, "gamma delta");
        let span = t.next_line_into(u64::MAX, &mut line).unwrap();
        assert_eq!(span, Some((23, 31)));
        assert_eq!(line, "epsilon");
        assert_eq!(t.next_line_into(u64::MAX, &mut line).unwrap(), None);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn limit_is_respected() {
        let p = tmp("limit", b"one\ntwo\nthree\n");
        let mut t = TailReader::open(&p, 0).unwrap();
        let mut line = String::new();
        assert!(t.next_line_into(4, &mut line).unwrap().is_some());
        assert_eq!(line, "one");
        // Cursor is at 4 == limit: nothing more inside the window.
        assert_eq!(t.next_line_into(4, &mut line).unwrap(), None);
        // A wider window resumes exactly where we stopped.
        assert!(t.next_line_into(8, &mut line).unwrap().is_some());
        assert_eq!(line, "two");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn open_past_eof_waits_for_growth() {
        let p = tmp("past_eof", b"short\n");
        let mut t = TailReader::open(&p, 6).unwrap();
        let mut line = String::new();
        assert_eq!(t.next_line_into(u64::MAX, &mut line).unwrap(), None);
        let mut f = OpenOptions::new().append(true).open(&p).unwrap();
        f.write_all(b"grown\n").unwrap();
        drop(f);
        assert_eq!(
            t.next_line_into(u64::MAX, &mut line).unwrap(),
            Some((6, 12))
        );
        assert_eq!(line, "grown");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn parse_follow_accepts_tcp_only() {
        assert_eq!(parse_follow("tcp:127.0.0.1:0").unwrap(), "127.0.0.1:0");
        assert!(parse_follow("udp:1.2.3.4:5").is_err());
        assert!(parse_follow("tcp:").is_err());
    }

    #[test]
    fn pump_appends_lines_and_completes_partial_tail() {
        use std::net::TcpStream;
        let p = tmp("pump", b"");
        let listener = follow_listener("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let pump = s.spawn(|| pump_tcp(&listener, &p, &stop));
            let mut c = TcpStream::connect(addr).unwrap();
            c.write_all(b"fed line one\nfed line").unwrap();
            drop(c); // partial "fed line" gets its newline at close
            // Wait until the feeder has flushed both lines.
            for _ in 0..200 {
                if std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0) >= 22 {
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            stop.store(true, Ordering::Release);
            let appended = pump.join().unwrap().unwrap();
            assert_eq!(appended, 22);
        });
        assert_eq!(std::fs::read(&p).unwrap(), b"fed line one\nfed line\n");
        std::fs::remove_file(&p).ok();
    }
}
