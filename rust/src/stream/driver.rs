//! The streaming trainer: tail a growing corpus and train continuously
//! over the arriving suffix with the batch trainer's exact sampling and
//! update pipeline.
//!
//! # Reproducibility contract
//!
//! The driver replays the single-shard batch worker loop line for line:
//! same RNG seeding (`seed ^ 17` for shard 0), same subsample → window
//! generation → superbatch flush order, same learning-rate bookkeeping.
//! Two consequences, both pinned by `tests/stream_parity.rs`:
//!
//! * a stream over a file that NEVER grows is bitwise identical to the
//!   batch run on the same bytes — streaming is a strict generalisation,
//!   not a different trainer;
//! * a stream killed and resumed from its checkpoint is bitwise
//!   identical to the uninterrupted stream, because checkpoints are only
//!   taken at superbatch flush boundaries (arena empty, word counter
//!   drained) where the whole trainer state is eight u64s plus the
//!   model.
//!
//! # Growth
//!
//! New bytes extend the learning-rate horizon (`LrState::extend_total`)
//! by their unclipped in-vocabulary token count — the same quantity the
//! batch vocabulary pass would have counted.  Out-of-vocabulary tokens
//! in fresh bytes feed the vocabulary candidate buffer; once a word's
//! count reaches `min_count` it is admitted into a pre-allocated
//! reserve row (`--vocab-reserve`), already initialised by the cold
//! model init's sequential RNG stream.  Admission rebuilds the unigram
//! alias table and extends the subsample keep-table (see
//! `Subsampler::extend_for_admitted` for why the prefix is frozen).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use crate::config::{Backend as BackendKind, CorpusCacheMode, LrSchedule, TrainConfig};
use crate::corpus::encoded::EncodedCorpus;
use crate::corpus::reader::MAX_SENTENCE_LEN;
use crate::corpus::subsample::Subsampler;
use crate::corpus::vocab::Vocab;
use crate::linalg::simd;
use crate::metrics::{Counters, Snapshot};
use crate::model::io as model_io;
use crate::model::{Embedding, SharedModel};
use crate::sampling::batch::{BatchBuilder, SuperbatchArena};
use crate::sampling::unigram::UnigramSampler;
use crate::serve::RowStore;
use crate::train::sgd_gemm::{GemmBackend, UpdateRule};
use crate::train::Backend;
use crate::train::LrState;
use crate::util::rng::Xoshiro256ss;

use super::ckpt::{self, StreamState};
use super::tail::{self, TailReader};

/// Knobs for a streaming run (everything else comes from
/// [`TrainConfig`]).
#[derive(Clone, Debug)]
pub struct StreamOptions {
    /// Checkpoint base path (PR-6 two-slot `PWCK` files plus the
    /// `.stream` sidecar).  `None` = never checkpoint.
    pub checkpoint: Option<PathBuf>,
    /// Superbatch flushes between checkpoints.
    pub ckpt_every: u64,
    /// Warm-restart from `checkpoint` when its sidecar exists.
    pub resume: bool,
    /// Sleep between file polls in [`run`](StreamTrainer::run).
    pub poll_ms: u64,
    /// Stop after this long with no new complete line (0 = run until
    /// killed).
    pub idle_ms: u64,
    /// `tcp:<addr>`: accept line-oriented socket connections and append
    /// them to the corpus file (the ingest feed).
    pub follow: Option<String>,
    /// Export a serve-ready [`RowStore`] here at every checkpoint (and
    /// at finish), for `serve --watch` hot-swapping.
    pub store: Option<PathBuf>,
}

impl Default for StreamOptions {
    fn default() -> Self {
        Self {
            checkpoint: None,
            ckpt_every: 8,
            resume: false,
            poll_ms: 50,
            idle_ms: 0,
            follow: None,
            store: None,
        }
    }
}

/// What a finished streaming run hands back.
#[derive(Debug)]
pub struct StreamOutcome {
    pub snapshot: Snapshot,
    pub final_lr: f32,
    /// Live vocabulary size (admissions included).
    pub vocab_len: usize,
    /// Words admitted during this process's lifetime.
    pub admitted: u64,
    /// Corpus bytes consumed (next unread line start).
    pub trained_bytes: u64,
}

/// Continuous trainer over a growing corpus file.
pub struct StreamTrainer {
    cfg: TrainConfig,
    corpus: PathBuf,
    opts: StreamOptions,
    vocab: Vocab,
    /// Vocab length at cold start (subsampler prefix; see sidecar docs).
    base_len: usize,
    model: SharedModel,
    backend: GemmBackend,
    sampler: UnigramSampler,
    subsampler: Subsampler,
    lr: LrState,
    counters: Counters,
    rng: Xoshiro256ss,
    tail: TailReader,
    /// Reused line buffer (steady state allocates nothing).
    line: String,
    /// Reused sentence buffer.
    sent: Vec<u32>,
    arena: SuperbatchArena,
    /// Words consumed since the last superbatch flush.
    raw_words: u64,
    /// Next unread line start (mirrors `tail.pos()` between polls).
    pos: u64,
    /// Corpus bytes whose word counts are in the lr horizon.
    observed_end: u64,
    /// Checkpoint sequence number; slot `seq % 2` alternates regardless
    /// of `ckpt_every`.
    ckpt_seq: u64,
    /// Encoded-cache target (resolved from `cfg.corpus_cache`).
    cache: Option<PathBuf>,
    /// Corpus bytes the on-disk cache covers (0 = none yet).
    cache_end: u64,
    /// Vocab fingerprint the cache was built under.
    cache_fp: u64,
}

fn check_stream_cfg(cfg: &TrainConfig) -> anyhow::Result<()> {
    cfg.validate()?;
    anyhow::ensure!(
        matches!(cfg.backend, BackendKind::Gemm),
        "stream: only the gemm backend is supported (its updates are \
         stateless, which is what makes kill/resume bitwise); got {:?}",
        cfg.backend
    );
    anyhow::ensure!(
        cfg.epochs == 1,
        "stream: epochs must be 1 (a stream has no epoch boundary); got {}",
        cfg.epochs
    );
    anyhow::ensure!(
        cfg.threads == 1,
        "stream: single worker only (the checkpoint cursor is a single \
         file offset); got threads={}",
        cfg.threads
    );
    anyhow::ensure!(
        !matches!(cfg.lr_schedule, LrSchedule::DistScaled),
        "stream: lr-schedule dist-scaled is for multi-node runs; use linear"
    );
    anyhow::ensure!(
        matches!(cfg.lr_schedule, LrSchedule::Linear),
        "stream: per-parameter lr schedules are not supported; use linear"
    );
    Ok(())
}

fn gemm_backend(cfg: &TrainConfig) -> GemmBackend {
    GemmBackend::new(cfg.dim, cfg.batch, cfg.samples())
        .with_rule(UpdateRule::Plain)
        .with_sigmoid(cfg.sigmoid_mode)
        .with_kernel(cfg.kernel)
        .with_reuse(cfg.reuse)
}

fn cache_target(cfg: &TrainConfig, corpus: &Path) -> Option<PathBuf> {
    match &cfg.corpus_cache {
        CorpusCacheMode::Off => None,
        CorpusCacheMode::Auto => Some(EncodedCorpus::cache_path_for(corpus)),
        CorpusCacheMode::Path(p) => Some(p.clone()),
    }
}

impl StreamTrainer {
    /// Open a streaming run: resume from `opts.checkpoint` when asked
    /// and possible, cold-start otherwise (PR-6 warm-restart
    /// semantics: `--resume` with no checkpoint yet is a fresh run, so
    /// one flag works for both the first launch and every relaunch).
    pub fn open(cfg: &TrainConfig, corpus: &Path, opts: StreamOptions) -> anyhow::Result<Self> {
        check_stream_cfg(cfg)?;
        simd::configure(cfg.simd)?;
        if opts.resume {
            if let Some(base) = opts.checkpoint.clone() {
                if ckpt::sidecar_path(&base).exists() {
                    return Self::resumed(cfg, corpus, opts, &base);
                }
                eprintln!(
                    "stream: no sidecar at {} yet; cold-starting",
                    ckpt::sidecar_path(&base).display()
                );
            }
        }
        Self::cold(cfg, corpus, opts)
    }

    fn cold(cfg: &TrainConfig, corpus: &Path, opts: StreamOptions) -> anyhow::Result<Self> {
        let vocab = Vocab::build_from_file(corpus, cfg.min_count)?;
        anyhow::ensure!(
            !vocab.is_empty(),
            "stream: no word in {} meets min_count {} — seed the corpus \
             with at least one countable line before streaming",
            corpus.display(),
            cfg.min_count
        );
        let file_len = std::fs::metadata(corpus)?.len();
        let model =
            SharedModel::init_with_reserve(vocab.len(), cfg.vocab_reserve, cfg.dim, cfg.seed);
        let sampler = UnigramSampler::alias(&vocab, cfg.unigram_power);
        let subsampler = Subsampler::new(&vocab, cfg.sample);
        let lr = LrState::linear(cfg.lr, cfg.lr_min_frac, vocab.total_words());
        // Shard 0 of the batch worker pool: seed ^ (0 * mix + 17).
        let rng = Xoshiro256ss::new(cfg.seed ^ 17);
        let cache = cache_target(cfg, corpus);
        // Adopt a pre-built encoded cache when it matches this
        // vocabulary and covers a prefix of the current file.
        let (mut cache_end, mut cache_fp) = (0u64, 0u64);
        if let Some(c) = &cache {
            if let Ok(enc) = EncodedCorpus::open(c, &vocab) {
                if enc.text_len() <= file_len {
                    cache_end = enc.text_len();
                    cache_fp = vocab.fingerprint();
                }
            }
        }
        let base_len = vocab.len();
        Ok(Self {
            cfg: cfg.clone(),
            corpus: corpus.to_path_buf(),
            opts,
            vocab,
            base_len,
            model,
            backend: gemm_backend(cfg),
            sampler,
            subsampler,
            lr,
            counters: Counters::new(),
            rng,
            tail: TailReader::open(corpus, 0)?,
            line: String::with_capacity(4096),
            sent: Vec::with_capacity(MAX_SENTENCE_LEN),
            arena: SuperbatchArena::with_sentence_slack(cfg.superbatch, cfg.batch, cfg.samples()),
            raw_words: 0,
            pos: 0,
            // The initial bytes are already counted in total_words().
            observed_end: file_len,
            ckpt_seq: 0,
            cache,
            cache_end,
            cache_fp,
        })
    }

    fn resumed(
        cfg: &TrainConfig,
        corpus: &Path,
        opts: StreamOptions,
        base: &Path,
    ) -> anyhow::Result<Self> {
        let st = ckpt::load_state(base)?;
        let mut vocab = Vocab::from_saved_parts(st.words, st.counts, st.generation)?;
        for (w, c) in &st.candidates {
            vocab.restore_candidate(w, *c);
        }
        let slot = (st.round % 2) as usize;
        let ck = model_io::load_checkpoint(model_io::checkpoint_slot_path(base, 0, slot))?;
        anyhow::ensure!(
            ck.round == st.round,
            "stream resume: sidecar is at checkpoint {} but PWCK slot {} \
             holds checkpoint {} (mixed files from different runs?)",
            st.round,
            slot,
            ck.round
        );
        let want = cfg.fingerprint() ^ vocab.fingerprint() ^ 1;
        anyhow::ensure!(
            ck.fingerprint == want,
            "stream resume: checkpoint fingerprint {:#x} != expected {:#x} \
             (config or vocabulary changed since the checkpoint)",
            ck.fingerprint,
            want
        );
        anyhow::ensure!(
            ck.m_in.vocab() >= vocab.len() && ck.m_in.dim() == cfg.dim,
            "stream resume: model {}x{} cannot serve vocab {} dim {}",
            ck.m_in.vocab(),
            ck.m_in.dim(),
            vocab.len(),
            cfg.dim
        );
        let file_len = std::fs::metadata(corpus)?.len();
        anyhow::ensure!(
            file_len >= st.pos,
            "stream resume: {} is {} bytes but the checkpoint cursor is at \
             {} — the corpus shrank since the checkpoint",
            corpus.display(),
            file_len,
            st.pos
        );
        let rng = Xoshiro256ss::from_state(ck.rng);
        let lr = LrState::linear(cfg.lr, cfg.lr_min_frac, 1);
        lr.restore_total(st.lr_total);
        lr.restore(ck.lr_words);
        let counters = Counters::new();
        counters.add_words(ck.words_done);
        let sampler = UnigramSampler::alias(&vocab, cfg.unigram_power);
        // Rebuild the subsampler the running streamer had: the cold
        // prefix's probabilities from the cold counts, admitted rows at
        // keep=1.  `Subsampler::new` over the grown vocab would instead
        // recompute EVERY prefix probability under the larger total.
        let mut subsampler = Subsampler::new(&vocab.truncated(st.base_len as usize), cfg.sample);
        subsampler.extend_for_admitted(vocab.len());
        let model = SharedModel::new(ck.m_in, ck.m_out);
        eprintln!(
            "stream: resumed checkpoint {} at byte {} ({} live words, generation {})",
            st.round,
            st.pos,
            vocab.len(),
            vocab.generation()
        );
        Ok(Self {
            cfg: cfg.clone(),
            corpus: corpus.to_path_buf(),
            opts,
            base_len: st.base_len as usize,
            model,
            backend: gemm_backend(cfg),
            sampler,
            subsampler,
            lr,
            counters,
            rng,
            tail: TailReader::open(corpus, st.pos)?,
            line: String::with_capacity(4096),
            sent: Vec::with_capacity(MAX_SENTENCE_LEN),
            arena: SuperbatchArena::with_sentence_slack(cfg.superbatch, cfg.batch, cfg.samples()),
            raw_words: 0,
            pos: st.pos,
            observed_end: st.observed_end,
            ckpt_seq: st.round,
            cache: cache_target(cfg, corpus),
            cache_end: st.cache_end,
            cache_fp: st.cache_fp,
            vocab,
        })
    }

    // ---- accessors (tests, cli reporting) ----------------------------

    pub fn model(&self) -> &SharedModel {
        &self.model
    }

    pub fn vocab(&self) -> &Vocab {
        &self.vocab
    }

    pub fn pos(&self) -> u64 {
        self.pos
    }

    pub fn lr_current(&self) -> f32 {
        self.lr.current()
    }

    pub fn snapshot(&self) -> Snapshot {
        self.counters.snapshot()
    }

    // ---- the loop ----------------------------------------------------

    /// Admit every due candidate for which a reserve row remains, then
    /// consume every complete line up to `limit` (pass the current file
    /// length; tests pass explicit byte windows to replay a growth
    /// schedule deterministically).  Returns whether any line was
    /// consumed.
    pub fn poll_once(&mut self, limit: u64) -> anyhow::Result<bool> {
        self.maybe_admit()?;
        let mut progressed = false;
        loop {
            let Some((_, line_end)) = self.tail.next_line_into(limit, &mut self.line)? else {
                break;
            };
            self.process_line(line_end)?;
            progressed = true;
        }
        Ok(progressed)
    }

    /// One line through the batch worker's exact pipeline.
    fn process_line(&mut self, line_end: u64) -> anyhow::Result<()> {
        let fresh = line_end > self.observed_end;
        let observe_oov = fresh && self.model.vocab() > self.vocab.len();
        self.sent.clear();
        let mut fresh_tokens = 0u64;
        for tok in self.line.split_ascii_whitespace() {
            match self.vocab.id(tok) {
                Some(id) => {
                    fresh_tokens += 1;
                    // Same clip as SentenceReader: at most
                    // MAX_SENTENCE_LEN ids per line.  (The horizon
                    // count above stays unclipped — it mirrors the
                    // vocabulary pass, which never clipped.)
                    if self.sent.len() < MAX_SENTENCE_LEN {
                        self.sent.push(id);
                    }
                }
                None => {
                    if observe_oov {
                        self.vocab.observe(tok);
                    }
                }
            }
        }
        if fresh {
            self.lr.extend_total(fresh_tokens);
            self.observed_end = line_end;
        }
        self.pos = line_end;
        if self.sent.is_empty() {
            // SentenceReader never surfaces empty sentences; consuming
            // no RNG here keeps the streams aligned.
            return Ok(());
        }
        self.raw_words += self.sent.len() as u64;
        self.subsampler.filter(&mut self.sent, &mut self.rng);
        // Built per sentence (the sampler lives in `self`, so a held
        // builder would self-borrow).  Under `--reuse sentence` every
        // fresh builder stamps serial 0; consecutive sentences in one
        // arena then share a serial, and the reuse driver's
        // slots-equality check is what keeps their runs apart (equal
        // negatives across sentences would merge — which IS the defined
        // reuse semantics, deterministically).
        let mut builder = BatchBuilder::new(
            &self.sampler,
            self.cfg.window,
            self.cfg.batch,
            self.cfg.negative,
        )
        .with_reuse(self.cfg.reuse);
        builder.fill_arena(&self.sent, &mut self.rng, &mut self.arena);
        if self.arena.len() >= self.cfg.superbatch {
            self.flush()?;
        }
        Ok(())
    }

    /// Superbatch flush — verbatim the batch worker's flush block, plus
    /// the checkpoint cadence hook (flush boundaries are the only
    /// points where trainer state is small enough to snapshot).
    fn flush(&mut self) -> anyhow::Result<()> {
        let lr = self.lr.advance(self.raw_words);
        self.counters.add_words(self.raw_words);
        self.raw_words = 0;
        self.backend
            .process_arena(self.model.store(), &self.arena, lr)?;
        self.counters.add_windows(self.arena.len() as u64);
        self.counters.add_calls(1);
        self.arena.clear();
        if self.opts.checkpoint.is_some() && self.counters.snapshot().calls % self.opts.ckpt_every.max(1) == 0
        {
            self.checkpoint()?;
        }
        Ok(())
    }

    /// Admit due candidates into reserve rows and rebuild the sampling
    /// tables.  No-op (and allocation-free) while nothing is due.
    fn maybe_admit(&mut self) -> anyhow::Result<()> {
        if self.vocab.candidate_len() == 0 || self.model.vocab() <= self.vocab.len() {
            return Ok(());
        }
        let due = self.vocab.admissible(self.cfg.min_count);
        if due.is_empty() {
            return Ok(());
        }
        let mut admitted = 0u64;
        for (word, _count) in due {
            if self.vocab.len() >= self.model.vocab() {
                break;
            }
            if self.vocab.admit(&word).is_some() {
                admitted += 1;
            }
        }
        if admitted == 0 {
            return Ok(());
        }
        self.sampler = UnigramSampler::alias(&self.vocab, self.cfg.unigram_power);
        self.subsampler.extend_for_admitted(self.vocab.len());
        self.counters.add_admissions(admitted);
        eprintln!(
            "stream: admitted {admitted} words ({} live / {} rows, generation {})",
            self.vocab.len(),
            self.model.vocab(),
            self.vocab.generation()
        );
        Ok(())
    }

    /// Bring the encoded cache up to the cursor.  Lazy: called at
    /// checkpoint/finish events only, so the steady-state loop never
    /// touches it.  `pos` always ends at a complete line, so the
    /// append path's newline precondition holds by construction.
    fn sync_cache(&mut self) -> anyhow::Result<()> {
        let Some(cache) = self.cache.clone() else {
            return Ok(());
        };
        if self.pos == 0 || self.cache_end >= self.pos {
            return Ok(());
        }
        let fresh_fp = self.vocab.fingerprint();
        let rebuild = self.cache_end == 0 || self.cache_fp != fresh_fp;
        if rebuild {
            EncodedCorpus::build_upto(&self.corpus, &self.vocab, &cache, self.pos)?;
        } else if let Err(why) =
            EncodedCorpus::append(&self.corpus, &self.vocab, &cache, self.cache_fp, self.pos)
        {
            eprintln!("stream: cache append failed ({why:#}); rebuilding");
            EncodedCorpus::build_upto(&self.corpus, &self.vocab, &cache, self.pos)?;
        }
        self.cache_end = self.pos;
        self.cache_fp = fresh_fp;
        Ok(())
    }

    /// Snapshot model + cursor.  Must only run at a flush boundary.
    fn checkpoint(&mut self) -> anyhow::Result<()> {
        let Some(base) = self.opts.checkpoint.clone() else {
            return Ok(());
        };
        debug_assert!(self.arena.is_empty() && self.raw_words == 0);
        self.sync_cache()?;
        self.ckpt_seq += 1;
        let ck = model_io::Checkpoint {
            rank: 0,
            nranks: 1,
            round: self.ckpt_seq,
            epoch: 0,
            sentences_in_epoch: 0,
            words_done: self.counters.words_now(),
            lr_words: self.lr.words_done(),
            rng: self.rng.state(),
            fingerprint: self.cfg.fingerprint() ^ self.vocab.fingerprint() ^ 1,
            m_in: self.model.m_in().clone(),
            m_out: self.model.m_out().clone(),
        };
        let slot = (self.ckpt_seq % 2) as usize;
        model_io::save_checkpoint(model_io::checkpoint_slot_path(&base, 0, slot), &ck)?;
        // Sidecar LAST: a loaded sidecar always references a
        // fully-written PWCK slot.
        ckpt::save_state(&base, &self.state_snapshot())?;
        self.export_store()?;
        Ok(())
    }

    fn state_snapshot(&self) -> StreamState {
        StreamState {
            round: self.ckpt_seq,
            pos: self.pos,
            observed_end: self.observed_end,
            base_len: self.base_len as u64,
            lr_total: self.lr.total(),
            cache_end: self.cache_end,
            cache_fp: self.cache_fp,
            generation: self.vocab.generation(),
            words: (0..self.vocab.len() as u32)
                .map(|i| self.vocab.word(i).to_string())
                .collect(),
            counts: self.vocab.counts().to_vec(),
            candidates: self
                .vocab
                .candidates()
                .map(|(w, c)| (w.to_string(), c))
                .collect(),
        }
    }

    /// Export the live rows as a serve-ready [`RowStore`] (generation =
    /// checkpoint sequence, so `serve` stats expose swap progress).
    /// The model keeps reserve rows past the live vocabulary; the store
    /// gets exactly the live prefix.
    fn export_store(&self) -> anyhow::Result<()> {
        let Some(path) = &self.opts.store else {
            return Ok(());
        };
        let live = self.vocab.len();
        let mut emb = Embedding::zeros(live, self.model.dim());
        for id in 0..live as u32 {
            emb.row_mut(id).copy_from_slice(self.model.m_in().row(id));
        }
        let words: Vec<String> = (0..live as u32)
            .map(|i| self.vocab.word(i).to_string())
            .collect();
        let mut store = RowStore::from_model(words, &emb)?;
        store.set_generation(self.ckpt_seq);
        store.save(path)?;
        Ok(())
    }

    /// Drain the ragged tail (the batch epilogue), take a final
    /// checkpoint, and report.
    pub fn finish(&mut self) -> anyhow::Result<StreamOutcome> {
        if !self.arena.is_empty() {
            let lr = self.lr.advance(self.raw_words);
            self.counters.add_words(self.raw_words);
            self.raw_words = 0;
            self.backend
                .process_arena(self.model.store(), &self.arena, lr)?;
            self.counters.add_windows(self.arena.len() as u64);
            self.counters.add_calls(1);
            self.arena.clear();
        } else if self.raw_words > 0 {
            self.lr.advance(self.raw_words);
            self.counters.add_words(self.raw_words);
            self.raw_words = 0;
        }
        if self.opts.checkpoint.is_some() {
            self.checkpoint()?;
        } else {
            self.sync_cache()?;
            self.export_store()?;
        }
        let snapshot = self.counters.snapshot();
        Ok(StreamOutcome {
            snapshot,
            final_lr: self.lr.current(),
            vocab_len: self.vocab.len(),
            admitted: snapshot.admissions,
            trained_bytes: self.pos,
        })
    }

    /// Poll-train until the idle deadline passes (or forever when
    /// `idle_ms` is 0 — the kill-and-`--resume` deployment mode), with
    /// the optional `--follow tcp:` ingest feed appending to the corpus
    /// in a side thread.
    pub fn run(&mut self) -> anyhow::Result<StreamOutcome> {
        let listener = match &self.opts.follow {
            Some(spec) => {
                let l = tail::follow_listener(tail::parse_follow(spec)?)?;
                eprintln!("stream: ingest feed listening on {}", l.local_addr()?);
                Some(l)
            }
            None => None,
        };
        let stop = AtomicBool::new(false);
        let corpus = self.corpus.clone();
        let poll = Duration::from_millis(self.opts.poll_ms.max(1));
        let idle_ms = self.opts.idle_ms;
        std::thread::scope(|scope| -> anyhow::Result<()> {
            let feeder = listener.as_ref().map(|l| {
                let corpus = corpus.clone();
                let stop = &stop;
                scope.spawn(move || tail::pump_tcp(l, &corpus, stop))
            });
            let mut last_progress = Instant::now();
            loop {
                let len = std::fs::metadata(&self.corpus)?.len();
                if self.poll_once(len)? {
                    last_progress = Instant::now();
                } else if idle_ms > 0
                    && last_progress.elapsed() >= Duration::from_millis(idle_ms)
                {
                    break;
                }
                std::thread::sleep(poll);
            }
            stop.store(true, Ordering::Release);
            if let Some(f) = feeder {
                match f.join() {
                    Ok(Ok(bytes)) => {
                        eprintln!("stream: ingest feed closed ({bytes} bytes appended)")
                    }
                    Ok(Err(e)) => eprintln!("stream: ingest feed error: {e:#}"),
                    Err(_) => eprintln!("stream: ingest feed thread panicked"),
                }
            }
            Ok(())
        })?;
        self.finish()
    }
}
