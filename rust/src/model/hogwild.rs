//! Hogwild shared-model wrapper: lock-free concurrent mutation of the two
//! embedding matrices (Niu et al. 2011, as used by word2vec and by the
//! paper's "Hogwild over GEMM blocks" scheme, Sec. III-C).
//!
//! # Safety model
//!
//! Hogwild updates are *deliberately racy*: threads read and write model
//! rows without synchronisation, accepting lost/torn updates as algorithmic
//! noise (the paper's convergence argument).  Rust's reference model cannot
//! express "benign" data races through `&mut`, so this wrapper hands out
//! raw-pointer row views.  Two invariants keep this sound enough in
//! practice (identical to the C original's guarantees):
//!
//! * the allocation is owned by [`SharedModel`] and outlives all workers
//!   (workers borrow the `SharedModel`, enforced by scoped threads);
//! * reads/writes are plain f32 loads/stores — torn values are possible in
//!   principle but are exactly the approximation Hogwild admits.
//!
//! All mutation flows through `row_in/row_out` + `apply_delta`, keeping the
//! unsafety in one audited module.

use super::embedding::Embedding;
use crate::linalg::simd::axpy;

/// The shared `{M_in, M_out}` pair of the paper's Ω.
pub struct SharedModel {
    m_in: Embedding,
    m_out: Embedding,
}

// SAFETY: see module docs — concurrent mutation is the Hogwild contract.
unsafe impl Sync for SharedModel {}

impl SharedModel {
    pub fn new(m_in: Embedding, m_out: Embedding) -> Self {
        assert_eq!(m_in.vocab(), m_out.vocab());
        assert_eq!(m_in.dim(), m_out.dim());
        Self { m_in, m_out }
    }

    /// Standard word2vec init: `M_in` uniform, `M_out` zeros.
    pub fn init(vocab: usize, dim: usize, seed: u64) -> Self {
        Self::new(
            Embedding::uniform_init(vocab, dim, seed),
            Embedding::zeros(vocab, dim),
        )
    }

    pub fn vocab(&self) -> usize {
        self.m_in.vocab()
    }

    pub fn dim(&self) -> usize {
        self.m_in.dim()
    }

    /// Immutable view of the input matrix (evaluation path, single-threaded).
    pub fn m_in(&self) -> &Embedding {
        &self.m_in
    }

    pub fn m_out(&self) -> &Embedding {
        &self.m_out
    }

    /// Exclusive views (setup / sync phases where `&mut self` is held).
    pub fn m_in_mut(&mut self) -> &mut Embedding {
        &mut self.m_in
    }

    pub fn m_out_mut(&mut self) -> &mut Embedding {
        &mut self.m_out
    }

    /// Racy mutable view of an input row.
    ///
    /// # Safety
    /// Caller must be a Hogwild worker scoped inside the model's lifetime;
    /// concurrent calls on the same row are permitted by the algorithm.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_in(&self, w: u32) -> &mut [f32] {
        let o = w as usize * self.m_in.stride();
        std::slice::from_raw_parts_mut(
            (self.m_in.as_ptr() as *mut f32).add(o),
            self.m_in.dim(),
        )
    }

    /// Racy mutable view of an output row (same contract as [`row_in`]).
    ///
    /// # Safety
    /// See [`Self::row_in`].
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_out(&self, w: u32) -> &mut [f32] {
        let o = w as usize * self.m_out.stride();
        std::slice::from_raw_parts_mut(
            (self.m_out.as_ptr() as *mut f32).add(o),
            self.m_out.dim(),
        )
    }

    /// Scatter-add a delta into an input row (`M_in[w] += delta`).
    #[inline]
    pub fn add_in(&self, w: u32, delta: &[f32]) {
        // SAFETY: Hogwild contract (module docs).
        unsafe { axpy(1.0, delta, self.row_in(w)) }
    }

    /// Scatter-add a delta into an output row.
    #[inline]
    pub fn add_out(&self, w: u32, delta: &[f32]) {
        // SAFETY: Hogwild contract (module docs).
        unsafe { axpy(1.0, delta, self.row_out(w)) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn init_shapes() {
        let m = SharedModel::init(100, 32, 1);
        assert_eq!(m.vocab(), 100);
        assert_eq!(m.dim(), 32);
        // M_out starts zero, M_in doesn't.
        assert!(m.m_out().data().iter().all(|&x| x == 0.0));
        assert!(m.m_in().data().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn add_applies_delta() {
        let m = SharedModel::init(10, 4, 2);
        let before = m.m_in().row(3).to_vec();
        m.add_in(3, &[1.0, 2.0, 3.0, 4.0]);
        let after = m.m_in().row(3);
        for i in 0..4 {
            assert!((after[i] - before[i] - (i + 1) as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn concurrent_disjoint_updates_all_land() {
        // With disjoint rows there are no conflicts, so every update must
        // be applied exactly.
        let m = SharedModel::init(64, 8, 3);
        thread::scope(|s| {
            for t in 0..4u32 {
                let m = &m;
                s.spawn(move || {
                    for _ in 0..1000 {
                        for w in (t * 16)..(t * 16 + 16) {
                            m.add_out(w, &[1.0; 8]);
                        }
                    }
                });
            }
        });
        for w in 0..64u32 {
            for &x in m.m_out().row(w) {
                assert_eq!(x, 1000.0, "row {w}");
            }
        }
    }

    #[test]
    fn concurrent_conflicting_updates_mostly_land() {
        // Hogwild on the SAME row: losses are allowed but must be a small
        // fraction on this hardware (sanity check of the coherence story).
        let m = SharedModel::init(1, 8, 4);
        let per_thread = 50_000;
        let threads = 4;
        thread::scope(|s| {
            for _ in 0..threads {
                let m = &m;
                s.spawn(move || {
                    for _ in 0..per_thread {
                        m.add_out(0, &[1.0; 8]);
                    }
                });
            }
        });
        let expected = (per_thread * threads) as f32;
        for &x in m.m_out().row(0) {
            assert!(x > expected * 0.5, "lost too many updates: {x}/{expected}");
            assert!(x <= expected + 0.5);
        }
    }
}
