//! Hogwild shared-model wrapper: lock-free concurrent mutation of the two
//! embedding matrices (Niu et al. 2011, as used by word2vec and by the
//! paper's "Hogwild over GEMM blocks" scheme, Sec. III-C).
//!
//! # Safety model
//!
//! Hogwild updates are *deliberately racy*: threads read and write model
//! rows without synchronisation, accepting lost/torn updates as algorithmic
//! noise (the paper's convergence argument).  Rust's reference model cannot
//! express "benign" data races through `&mut`, so this wrapper hands out
//! raw-pointer row views.  Two invariants keep this sound enough in
//! practice (identical to the C original's guarantees):
//!
//! * the allocation is owned by [`SharedModel`] and outlives all workers
//!   (workers borrow the `SharedModel`, enforced by scoped threads);
//! * reads/writes are plain f32 loads/stores — torn values are possible in
//!   principle but are exactly the approximation Hogwild admits.
//!
//! All mutation flows through `row_in/row_out` + `apply_delta`, keeping the
//! unsafety in one audited module.
//!
//! # NUMA sharding
//!
//! Two storage layouts sit behind the [`ModelRef`] dispatcher the
//! trainer back-ends program against:
//!
//! * [`SharedModel`] — the flat pair of `[V, D]` matrices (the pre-NUMA
//!   layout, `--numa off` bit-for-bit).  Under Linux first-touch paging
//!   the whole model lands on the allocating thread's node, so on a
//!   multi-socket box every worker on the other socket crosses the
//!   interconnect for every row it gathers or scatters.
//! * [`NumaModel`] — row ranges split per NUMA node by a [`ShardMap`],
//!   each node's segment allocated AND first-written by a thread pinned
//!   to that node (`runtime::topology`), so its pages are node-local.
//!   `row_in`/`row_out`/the `add_*` scatters route through the shard map;
//!   values are bit-for-bit the flat layout's (only page placement
//!   changes), which is what makes the `--numa off` ≡ sharded 1-thread
//!   parity suite (`tests/numa_parity.rs`) possible.
//!
//! [`ModelRef`] is a `Copy` enum rather than a trait object on purpose:
//! row gathers/scatters are the hot loop, and an enum match devirtualises
//! to a perfectly-predicted branch with the flat path's pointer math
//! still inlined — `--numa off` keeps pre-NUMA codegen, not just
//! pre-NUMA values.

use super::embedding::{uniform_init_row, Embedding};
use crate::linalg::simd::axpy;
use crate::runtime::topology::Topology;
use crate::util::rng::Xoshiro256ss;
use crate::util::split_point;

/// Debug-build instrumentation of sharded row locality (the routing PR's
/// acceptance counter): every `NumaModel` row access made from a thread
/// that declared its home node via [`set_access_node`] is counted as
/// total/remote ("remote" = the row's home shard differs from the
/// accessing worker's node).  Threads that never declare a node (the
/// copy-back epilogue, eval, tests' main threads) are not counted, and
/// the flat `SharedModel` path never counts — so the stats isolate
/// exactly the cross-node Hogwild traffic `--route` attacks.  Release
/// builds compile all of it away ([`row_access_stats`] is always
/// `(0, 0)` there), keeping `--numa` hot-path codegen untouched.
#[cfg(debug_assertions)]
mod access_stats {
    use std::cell::Cell;
    use std::sync::atomic::AtomicU64;

    pub static TOTAL: AtomicU64 = AtomicU64::new(0);
    pub static REMOTE: AtomicU64 = AtomicU64::new(0);

    thread_local! {
        pub static NODE: Cell<Option<usize>> = const { Cell::new(None) };
    }
}

/// Declare the calling worker thread's home node for the debug
/// remote-row counters (`None` stops counting on this thread).  The
/// trainer calls this right after pinning, with the node the worker was
/// ASSIGNED — so the stats measure shard-map geometry even where
/// best-effort pinning failed.  No-op in release builds.
pub fn set_access_node(node: Option<usize>) {
    #[cfg(debug_assertions)]
    access_stats::NODE.with(|n| n.set(node));
    #[cfg(not(debug_assertions))]
    let _ = node;
}

/// `(total, remote)` sharded row accesses counted so far across all
/// declared threads (debug builds; always `(0, 0)` in release).  Tests
/// take before/after deltas — see `tests/routing_parity.rs`, which
/// serialises its training runs around these process-wide counters.
pub fn row_access_stats() -> (u64, u64) {
    #[cfg(debug_assertions)]
    {
        use std::sync::atomic::Ordering;
        (
            access_stats::TOTAL.load(Ordering::Relaxed),
            access_stats::REMOTE.load(Ordering::Relaxed),
        )
    }
    #[cfg(not(debug_assertions))]
    {
        (0, 0)
    }
}

/// Zero the process-wide row-access counters (debug builds).
pub fn reset_row_access_stats() {
    #[cfg(debug_assertions)]
    {
        use std::sync::atomic::Ordering;
        access_stats::TOTAL.store(0, Ordering::Relaxed);
        access_stats::REMOTE.store(0, Ordering::Relaxed);
    }
}

/// Count one sharded row access homed on `node` against the calling
/// thread's declared node (debug builds only; free in release).
#[inline]
fn note_row_access(node: usize) {
    #[cfg(debug_assertions)]
    {
        use std::sync::atomic::Ordering;
        if let Some(cur) = access_stats::NODE.with(|n| n.get()) {
            access_stats::TOTAL.fetch_add(1, Ordering::Relaxed);
            if cur != node {
                access_stats::REMOTE.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    #[cfg(not(debug_assertions))]
    let _ = node;
}

/// The row-level model handle every trainer back-end programs against:
/// racy Hogwild row views plus the scatter-add helpers, dispatching to
/// the flat [`SharedModel`] or the NUMA-sharded [`NumaModel`].
#[derive(Clone, Copy)]
pub enum ModelRef<'a> {
    Flat(&'a SharedModel),
    Numa(&'a NumaModel),
}

impl<'a> ModelRef<'a> {
    #[inline]
    pub fn vocab(&self) -> usize {
        match self {
            ModelRef::Flat(m) => m.vocab(),
            ModelRef::Numa(m) => m.vocab(),
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        match self {
            ModelRef::Flat(m) => m.dim(),
            ModelRef::Numa(m) => m.dim(),
        }
    }

    /// Racy mutable view of an input row (borrowing the underlying
    /// model, not this `Copy` handle).
    ///
    /// # Safety
    /// Caller must be a Hogwild worker scoped inside the model's lifetime;
    /// concurrent calls on the same row are permitted by the algorithm
    /// (module docs).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_in(&self, w: u32) -> &'a mut [f32] {
        match *self {
            ModelRef::Flat(m) => m.row_in(w),
            ModelRef::Numa(m) => m.row_in(w),
        }
    }

    /// Racy mutable view of an output row (same contract as `row_in`).
    ///
    /// # Safety
    /// See [`Self::row_in`].
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_out(&self, w: u32) -> &'a mut [f32] {
        match *self {
            ModelRef::Flat(m) => m.row_out(w),
            ModelRef::Numa(m) => m.row_out(w),
        }
    }

    /// Scatter-add a delta into an input row (`M_in[w] += delta`).
    #[inline]
    pub fn add_in(&self, w: u32, delta: &[f32]) {
        // SAFETY: Hogwild contract (module docs).
        unsafe { axpy(1.0, delta, self.row_in(w)) }
    }

    /// Scatter-add a delta into an output row.
    #[inline]
    pub fn add_out(&self, w: u32, delta: &[f32]) {
        // SAFETY: Hogwild contract (module docs).
        unsafe { axpy(1.0, delta, self.row_out(w)) }
    }
}

impl<'a> From<&'a SharedModel> for ModelRef<'a> {
    fn from(m: &'a SharedModel) -> Self {
        ModelRef::Flat(m)
    }
}

impl<'a> From<&'a NumaModel> for ModelRef<'a> {
    fn from(m: &'a NumaModel) -> Self {
        ModelRef::Numa(m)
    }
}

/// The shared `{M_in, M_out}` pair of the paper's Ω.
pub struct SharedModel {
    m_in: Embedding,
    m_out: Embedding,
}

// SAFETY: see module docs — concurrent mutation is the Hogwild contract.
unsafe impl Sync for SharedModel {}

impl SharedModel {
    pub fn new(m_in: Embedding, m_out: Embedding) -> Self {
        assert_eq!(m_in.vocab(), m_out.vocab());
        assert_eq!(m_in.dim(), m_out.dim());
        Self { m_in, m_out }
    }

    /// Standard word2vec init: `M_in` uniform, `M_out` zeros.
    pub fn init(vocab: usize, dim: usize, seed: u64) -> Self {
        Self::new(
            Embedding::uniform_init(vocab, dim, seed),
            Embedding::zeros(vocab, dim),
        )
    }

    /// Init with `reserve` pre-allocated rows past the live vocabulary
    /// (streaming ingest, `--vocab-reserve`).  Because `uniform_init`
    /// draws ONE sequential RNG stream over rows, the first `vocab` rows
    /// are bitwise identical to `init(vocab, dim, seed)` — reserving
    /// rows never perturbs the live model, and an admitted word's row
    /// already carries exactly the init it would have had in a batch run
    /// over a vocabulary that included it at that id.
    pub fn init_with_reserve(
        vocab: usize,
        reserve: usize,
        dim: usize,
        seed: u64,
    ) -> Self {
        Self::init(vocab + reserve, dim, seed)
    }

    pub fn vocab(&self) -> usize {
        self.m_in.vocab()
    }

    pub fn dim(&self) -> usize {
        self.m_in.dim()
    }

    /// Immutable view of the input matrix (evaluation path, single-threaded).
    pub fn m_in(&self) -> &Embedding {
        &self.m_in
    }

    pub fn m_out(&self) -> &Embedding {
        &self.m_out
    }

    /// Exclusive views (setup / sync phases where `&mut self` is held).
    pub fn m_in_mut(&mut self) -> &mut Embedding {
        &mut self.m_in
    }

    pub fn m_out_mut(&mut self) -> &mut Embedding {
        &mut self.m_out
    }

    /// Racy mutable view of an input row.
    ///
    /// # Safety
    /// Caller must be a Hogwild worker scoped inside the model's lifetime;
    /// concurrent calls on the same row are permitted by the algorithm.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_in(&self, w: u32) -> &mut [f32] {
        self.m_in.racy_row(w)
    }

    /// Racy mutable view of an output row (same contract as [`row_in`]).
    ///
    /// # Safety
    /// See [`Self::row_in`].
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_out(&self, w: u32) -> &mut [f32] {
        self.m_out.racy_row(w)
    }

    /// Scatter-add a delta into an input row (`M_in[w] += delta`).
    #[inline]
    pub fn add_in(&self, w: u32, delta: &[f32]) {
        // SAFETY: Hogwild contract (module docs).
        unsafe { axpy(1.0, delta, self.row_in(w)) }
    }

    /// Scatter-add a delta into an output row.
    #[inline]
    pub fn add_out(&self, w: u32, delta: &[f32]) {
        // SAFETY: Hogwild contract (module docs).
        unsafe { axpy(1.0, delta, self.row_out(w)) }
    }

    /// Allocate WITHOUT initialising content pages: both matrices are
    /// zero-filled via the allocator's zeroed path, which on Linux maps
    /// untouched copy-on-write zero pages.  The first real WRITE places
    /// each page (first-touch) — pair with [`first_touch_init`] from a
    /// pinned thread so a distributed replica's pages land on its node
    /// (`dist::train` under `--numa`).
    ///
    /// [`first_touch_init`]: Self::first_touch_init
    pub fn alloc(vocab: usize, dim: usize) -> Self {
        Self::new(Embedding::zeros(vocab, dim), Embedding::zeros(vocab, dim))
    }

    /// Standard word2vec init written THROUGH the racy row views, so the
    /// calling (pinned) thread is the first toucher of every content
    /// page.  Bit-for-bit identical to [`Self::init`] with the same seed:
    /// the same sequential RNG stream over `M_in` rows, zeros in `M_out`
    /// (written explicitly — committing the page is the point).
    pub fn first_touch_init(&self, seed: u64) {
        let mut rng = Xoshiro256ss::new(seed);
        let dim = self.dim();
        for w in 0..self.vocab() as u32 {
            // SAFETY: Hogwild contract; init races are the caller's to
            // exclude (each dist replica is initialised by one thread).
            uniform_init_row(unsafe { self.row_in(w) }, dim, &mut rng);
            // SAFETY: as above.
            unsafe { self.row_out(w) }.fill(0.0);
        }
    }
}

impl SharedModel {
    /// This model as the back-end-facing [`ModelRef`] handle.
    #[inline]
    pub fn store(&self) -> ModelRef<'_> {
        ModelRef::Flat(self)
    }
}

/// Contiguous partition of the model's `0..vocab` rows across NUMA
/// nodes: node `i` owns rows `boundaries[i]..boundaries[i+1]`, computed
/// with the shared [`split_point`] rule corpus shards use.  Degenerate
/// geometries are legal: a single node owns everything; with more nodes
/// than rows some nodes own empty ranges (and never see a row access).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    vocab: usize,
    boundaries: Vec<u32>,
}

impl ShardMap {
    pub fn contiguous(vocab: usize, nodes: usize) -> Self {
        assert!(nodes >= 1, "shard map needs >= 1 node");
        assert!(vocab <= u32::MAX as usize);
        let boundaries = (0..=nodes as u64)
            .map(|i| split_point(vocab as u64, nodes as u64, i) as u32)
            .collect();
        Self { vocab, boundaries }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn nodes(&self) -> usize {
        self.boundaries.len() - 1
    }

    /// Rows owned by `node`.
    pub fn range(&self, node: usize) -> std::ops::Range<u32> {
        self.boundaries[node]..self.boundaries[node + 1]
    }

    /// `(node, row-local index)` of a global row — the hot-path routing
    /// every sharded row access goes through.  Arithmetic guess plus a
    /// ±1 fix-up (the floor-division boundaries keep any guess within
    /// one node of the answer for equal-ish ranges; the loops also cover
    /// degenerate empty-range geometries).
    #[inline]
    pub fn locate(&self, row: u32) -> (usize, u32) {
        debug_assert!((row as usize) < self.vocab, "row {row} out of range");
        let n = self.nodes() as u64;
        let mut g = ((row as u64 * n) / self.vocab as u64) as usize;
        while row < self.boundaries[g] {
            g -= 1;
        }
        while row >= self.boundaries[g + 1] {
            g += 1;
        }
        (g, row - self.boundaries[g])
    }
}

/// One node's slice of the model: local `[rows, D]` matrices whose pages
/// were first-touched by a thread pinned to that node.
struct NodeShard {
    m_in: Embedding,
    m_out: Embedding,
}

/// The NUMA-sharded model store: `M_in`/`M_out` row ranges per node
/// (paper Sec. IV's dual-socket setting; `--numa {auto,<nodes>}`).
///
/// Values are bit-for-bit the flat [`SharedModel`]'s — construction
/// copies rows from a source model and [`copy_back`](Self::copy_back)
/// returns them — so the sharded path changes WHERE rows live, never
/// what they hold.
pub struct NumaModel {
    map: ShardMap,
    dim: usize,
    shards: Vec<NodeShard>,
}

// SAFETY: same Hogwild contract as `SharedModel` — the segments are
// owned by this struct, outlive all scoped workers, and racy row access
// is the algorithm's admitted approximation.
unsafe impl Sync for NumaModel {}

impl NumaModel {
    /// Shard `src` across `topo`'s nodes.  Each node's segment is
    /// allocated and FIRST WRITTEN inside a thread pinned to that node,
    /// so under Linux first-touch policy its pages are node-local.
    /// Pinning is best-effort (synthetic test topologies name cpus that
    /// may not exist); the copied values are identical either way.
    pub fn from_model(src: &SharedModel, topo: &Topology) -> Self {
        let map = ShardMap::contiguous(src.vocab(), topo.nodes());
        let dim = src.dim();
        let mut shards: Vec<Option<NodeShard>> =
            (0..topo.nodes()).map(|_| None).collect();
        std::thread::scope(|scope| {
            for (node, slot) in shards.iter_mut().enumerate() {
                let map = &map;
                scope.spawn(move || {
                    topo.pin_to_node(node);
                    let range = map.range(node);
                    let rows = (range.end - range.start) as usize;
                    let mut m_in = Embedding::zeros(rows, dim);
                    let mut m_out = Embedding::zeros(rows, dim);
                    for (local, global) in range.enumerate() {
                        m_in.row_mut(local as u32)
                            .copy_from_slice(src.m_in().row(global));
                        m_out
                            .row_mut(local as u32)
                            .copy_from_slice(src.m_out().row(global));
                    }
                    *slot = Some(NodeShard { m_in, m_out });
                });
            }
        });
        Self {
            map,
            dim,
            shards: shards.into_iter().map(|s| s.expect("init joined")).collect(),
        }
    }

    pub fn shard_map(&self) -> &ShardMap {
        &self.map
    }

    /// This model as the back-end-facing [`ModelRef`] handle.
    #[inline]
    pub fn store(&self) -> ModelRef<'_> {
        ModelRef::Numa(self)
    }

    pub fn vocab(&self) -> usize {
        self.map.vocab()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Racy mutable view of an input row, routed through the shard map.
    ///
    /// # Safety
    /// Same Hogwild contract as [`SharedModel::row_in`].
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_in(&self, w: u32) -> &mut [f32] {
        let (node, local) = self.map.locate(w);
        note_row_access(node);
        self.shards[node].m_in.racy_row(local)
    }

    /// Racy mutable view of an output row, routed through the shard map.
    ///
    /// # Safety
    /// Same Hogwild contract as [`SharedModel::row_in`].
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_out(&self, w: u32) -> &mut [f32] {
        let (node, local) = self.map.locate(w);
        note_row_access(node);
        self.shards[node].m_out.racy_row(local)
    }

    /// Copy the trained rows back into a flat model (after all workers
    /// joined; the trainer returns results through the caller's
    /// `SharedModel`, so every downstream consumer — eval, save,
    /// allreduce — is layout-agnostic).  (Scatter-adds go through
    /// [`ModelRef::add_in`]/[`add_out`](ModelRef::add_out) — the single
    /// update entry point for both layouts.)
    pub fn copy_back(&self, dst: &SharedModel) {
        assert_eq!(dst.vocab(), self.map.vocab());
        assert_eq!(dst.dim(), self.dim);
        for w in 0..self.map.vocab() as u32 {
            // SAFETY: single-threaded epilogue; Hogwild contract.
            unsafe {
                dst.row_in(w).copy_from_slice(self.row_in(w));
                dst.row_out(w).copy_from_slice(self.row_out(w));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn init_shapes() {
        let m = SharedModel::init(100, 32, 1);
        assert_eq!(m.vocab(), 100);
        assert_eq!(m.dim(), 32);
        // M_out starts zero, M_in doesn't.
        assert!(m.m_out().data().iter().all(|&x| x == 0.0));
        assert!(m.m_in().data().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn reserve_rows_leave_live_prefix_bitwise_stable() {
        let plain = SharedModel::init(40, 16, 11);
        let reserved = SharedModel::init_with_reserve(40, 24, 16, 11);
        assert_eq!(reserved.vocab(), 64);
        for w in 0..40u32 {
            assert_eq!(plain.m_in().row(w), reserved.m_in().row(w), "row {w}");
        }
        // Reserved rows are real initialised rows, not zeros.
        assert!(reserved.m_in().row(63).iter().any(|&x| x != 0.0));
        assert!(reserved.m_out().data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn add_applies_delta() {
        let m = SharedModel::init(10, 4, 2);
        let before = m.m_in().row(3).to_vec();
        m.add_in(3, &[1.0, 2.0, 3.0, 4.0]);
        let after = m.m_in().row(3);
        for i in 0..4 {
            assert!((after[i] - before[i] - (i + 1) as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn concurrent_disjoint_updates_all_land() {
        // With disjoint rows there are no conflicts, so every update must
        // be applied exactly.
        let m = SharedModel::init(64, 8, 3);
        thread::scope(|s| {
            for t in 0..4u32 {
                let m = &m;
                s.spawn(move || {
                    for _ in 0..1000 {
                        for w in (t * 16)..(t * 16 + 16) {
                            m.add_out(w, &[1.0; 8]);
                        }
                    }
                });
            }
        });
        for w in 0..64u32 {
            for &x in m.m_out().row(w) {
                assert_eq!(x, 1000.0, "row {w}");
            }
        }
    }

    #[test]
    fn concurrent_conflicting_updates_mostly_land() {
        // Hogwild on the SAME row: losses are allowed but must be a small
        // fraction on this hardware (sanity check of the coherence story).
        let m = SharedModel::init(1, 8, 4);
        let per_thread = 50_000;
        let threads = 4;
        thread::scope(|s| {
            for _ in 0..threads {
                let m = &m;
                s.spawn(move || {
                    for _ in 0..per_thread {
                        m.add_out(0, &[1.0; 8]);
                    }
                });
            }
        });
        let expected = (per_thread * threads) as f32;
        for &x in m.m_out().row(0) {
            assert!(x > expected * 0.5, "lost too many updates: {x}/{expected}");
            assert!(x <= expected + 0.5);
        }
    }

    #[test]
    fn alloc_plus_first_touch_init_matches_init_bitwise() {
        let a = SharedModel::init(70, 24, 99);
        let b = SharedModel::alloc(70, 24);
        b.first_touch_init(99);
        assert_eq!(a.m_in().data(), b.m_in().data());
        assert_eq!(a.m_out().data(), b.m_out().data());
    }

    #[test]
    fn shard_map_partitions_exactly() {
        // (vocab, nodes) including uneven rows-per-node, a single node,
        // and more nodes than rows.
        for (vocab, nodes) in
            [(10usize, 3usize), (100, 1), (2, 4), (7, 7), (1000, 6), (1, 3)]
        {
            let map = ShardMap::contiguous(vocab, nodes);
            assert_eq!(map.nodes(), nodes);
            assert_eq!(map.range(0).start, 0);
            assert_eq!(map.range(nodes - 1).end, vocab as u32);
            let mut covered = 0u64;
            for i in 0..nodes {
                let r = map.range(i);
                assert!(r.start <= r.end, "({vocab},{nodes}) node {i}");
                if i + 1 < nodes {
                    assert_eq!(r.end, map.range(i + 1).start);
                }
                covered += (r.end - r.start) as u64;
            }
            assert_eq!(covered, vocab as u64, "({vocab},{nodes})");
            // locate agrees with the ranges for EVERY row.
            for row in 0..vocab as u32 {
                let (node, local) = map.locate(row);
                let r = map.range(node);
                assert!(
                    r.contains(&row),
                    "({vocab},{nodes}) row {row} -> node {node} {r:?}"
                );
                assert_eq!(local, row - r.start);
            }
        }
    }

    #[test]
    fn numa_model_roundtrip_is_bitwise() {
        // Sharded copy-in + copy-back reproduces the flat model exactly,
        // across node counts including empty shards (nodes > rows).
        for nodes in [1usize, 2, 3, 64] {
            let topo = crate::runtime::topology::Topology::single_node()
                .regroup(nodes);
            let src = SharedModel::init(50, 16, 7);
            let numa = NumaModel::from_model(&src, &topo);
            assert_eq!(numa.vocab(), 50);
            assert_eq!(numa.dim(), 16);
            for w in 0..50u32 {
                // SAFETY: single-threaded test.
                unsafe {
                    assert_eq!(&*numa.row_in(w), src.m_in().row(w));
                    assert_eq!(&*numa.row_out(w), src.m_out().row(w));
                }
            }
            let dst = SharedModel::init(50, 16, 1234); // different content
            numa.copy_back(&dst);
            assert_eq!(dst.m_in().data(), src.m_in().data());
            assert_eq!(dst.m_out().data(), src.m_out().data());
        }
    }

    /// Debug remote-row counters: only threads that declared a node
    /// count, and "remote" follows the shard map exactly.  (Runs on its
    /// own spawned thread so the declaration never leaks into sibling
    /// tests; no other lib test declares a node, so the global deltas
    /// here are exact.)
    #[test]
    fn row_access_counters_split_local_and_remote() {
        if !cfg!(debug_assertions) {
            eprintln!("skipping: row-access counters are debug-only");
            return;
        }
        let topo =
            crate::runtime::topology::Topology::single_node().regroup(2);
        let src = SharedModel::init(10, 4, 3);
        let numa = NumaModel::from_model(&src, &topo);
        // Rows 0..5 home on node 0, rows 5..10 on node 1.
        let (t0, r0) = row_access_stats();
        // Undeclared thread: accesses must not count.
        unsafe {
            let _ = numa.row_in(0);
            let _ = numa.row_out(9);
        }
        assert_eq!(row_access_stats(), (t0, r0), "undeclared thread counted");
        thread::scope(|s| {
            s.spawn(|| {
                set_access_node(Some(0));
                // 3 accesses on node 0 (local), 2 on node 1 (remote).
                unsafe {
                    let _ = numa.row_in(0);
                    let _ = numa.row_out(1);
                    let _ = numa.row_in(4);
                    let _ = numa.row_in(5);
                    let _ = numa.row_out(9);
                }
                set_access_node(None);
                unsafe {
                    let _ = numa.row_in(7); // after None: not counted
                }
            });
        });
        let (t1, r1) = row_access_stats();
        assert_eq!(t1 - t0, 5, "total accesses");
        assert_eq!(r1 - r0, 2, "remote accesses");
    }

    #[test]
    fn numa_model_scatters_route_through_shard_map() {
        let topo =
            crate::runtime::topology::Topology::single_node().regroup(3);
        let src = SharedModel::init(10, 4, 3);
        let numa = NumaModel::from_model(&src, &topo);
        // A row in every shard, updated through the ModelRef-facing
        // scatters (the single update entry point for both layouts).
        for w in [0u32, 4, 9] {
            numa.store().add_in(w, &[1.0, 2.0, 3.0, 4.0]);
            numa.store().add_out(w, &[4.0, 3.0, 2.0, 1.0]);
        }
        let dst = SharedModel::alloc(10, 4);
        numa.copy_back(&dst);
        for w in 0..10u32 {
            let (din, dout): (Vec<f32>, Vec<f32>) = (
                dst.m_in()
                    .row(w)
                    .iter()
                    .zip(src.m_in().row(w))
                    .map(|(a, b)| a - b)
                    .collect(),
                dst.m_out()
                    .row(w)
                    .iter()
                    .zip(src.m_out().row(w))
                    .map(|(a, b)| a - b)
                    .collect(),
            );
            if [0u32, 4, 9].contains(&w) {
                for (i, x) in din.iter().enumerate() {
                    assert!((x - (i + 1) as f32).abs() < 1e-6, "row {w}");
                }
                for (i, x) in dout.iter().enumerate() {
                    assert!((x - (4 - i) as f32).abs() < 1e-6, "row {w}");
                }
            } else {
                assert!(din.iter().all(|&x| x == 0.0), "row {w} touched");
                assert!(dout.iter().all(|&x| x == 0.0), "row {w} touched");
            }
        }
    }
}
