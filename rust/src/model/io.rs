//! word2vec vector-file persistence, both classic formats:
//!
//! * text:   header `V D\n`, then `word v1 v2 ... vD\n` per word;
//! * binary: header `V D\n`, then `word<SPACE>` + D little-endian f32s.
//!
//! Interoperable with gensim / the original distribution's tools.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::embedding::Embedding;
use crate::corpus::vocab::Vocab;

/// Save `M_in` (the word vectors) in text format.
pub fn save_text<P: AsRef<Path>>(
    path: P,
    vocab: &Vocab,
    emb: &Embedding,
) -> anyhow::Result<()> {
    anyhow::ensure!(vocab.len() == emb.vocab(), "vocab/matrix size mismatch");
    let mut w = BufWriter::with_capacity(1 << 20, std::fs::File::create(path)?);
    writeln!(w, "{} {}", vocab.len(), emb.dim())?;
    for id in 0..vocab.len() as u32 {
        write!(w, "{}", vocab.word(id))?;
        for &x in emb.row(id) {
            write!(w, " {x}")?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Save in binary format.
pub fn save_binary<P: AsRef<Path>>(
    path: P,
    vocab: &Vocab,
    emb: &Embedding,
) -> anyhow::Result<()> {
    anyhow::ensure!(vocab.len() == emb.vocab(), "vocab/matrix size mismatch");
    let mut w = BufWriter::with_capacity(1 << 20, std::fs::File::create(path)?);
    writeln!(w, "{} {}", vocab.len(), emb.dim())?;
    for id in 0..vocab.len() as u32 {
        write!(w, "{} ", vocab.word(id))?;
        for &x in emb.row(id) {
            w.write_all(&x.to_le_bytes())?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a text-format vector file: returns `(words, matrix)`.
pub fn load_text<P: AsRef<Path>>(path: P) -> anyhow::Result<(Vec<String>, Embedding)> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::with_capacity(1 << 20, f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let (v, d) = parse_header(&header)?;
    let mut words = Vec::with_capacity(v);
    let mut emb = Embedding::zeros(v, d);
    let mut line = String::new();
    for i in 0..v {
        line.clear();
        anyhow::ensure!(r.read_line(&mut line)? > 0, "truncated at row {i}");
        let mut it = line.split_ascii_whitespace();
        let word = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty vector line {i}"))?;
        words.push(word.to_string());
        let row = emb.row_mut(i as u32);
        for (j, slot) in row.iter_mut().enumerate() {
            let tok = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("row {i}: missing dim {j}"))?;
            *slot = tok.parse()?;
        }
    }
    Ok((words, emb))
}

/// Load a binary-format vector file.
pub fn load_binary<P: AsRef<Path>>(
    path: P,
) -> anyhow::Result<(Vec<String>, Embedding)> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::with_capacity(1 << 20, f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let (v, d) = parse_header(&header)?;
    let mut words = Vec::with_capacity(v);
    let mut emb = Embedding::zeros(v, d);
    for i in 0..v {
        // word bytes up to space
        let mut word = Vec::new();
        loop {
            let mut b = [0u8; 1];
            r.read_exact(&mut b)?;
            if b[0] == b' ' {
                break;
            }
            word.push(b[0]);
        }
        words.push(String::from_utf8(word)?);
        let row = emb.row_mut(i as u32);
        let mut buf = vec![0u8; 4 * d];
        r.read_exact(&mut buf)?;
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = f32::from_le_bytes(buf[4 * j..4 * j + 4].try_into().unwrap());
        }
        // trailing newline
        let mut nl = [0u8; 1];
        r.read_exact(&mut nl)?;
    }
    Ok((words, emb))
}

fn parse_header(line: &str) -> anyhow::Result<(usize, usize)> {
    let mut it = line.split_ascii_whitespace();
    let v = it
        .next()
        .ok_or_else(|| anyhow::anyhow!("bad header"))?
        .parse()?;
    let d = it
        .next()
        .ok_or_else(|| anyhow::anyhow!("bad header"))?
        .parse()?;
    Ok((v, d))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Vocab, Embedding) {
        let vocab = Vocab::build("b a a".split_whitespace(), 1);
        let mut emb = Embedding::zeros(2, 3);
        emb.row_mut(0).copy_from_slice(&[1.5, -2.0, 0.25]);
        emb.row_mut(1).copy_from_slice(&[0.0, 3.0, -0.125]);
        (vocab, emb)
    }

    #[test]
    fn text_roundtrip() {
        let (vocab, emb) = sample();
        let path = std::env::temp_dir().join("pw2v_io_text.vec");
        save_text(&path, &vocab, &emb).unwrap();
        let (words, got) = load_text(&path).unwrap();
        assert_eq!(words, vec!["a".to_string(), "b".to_string()]);
        for i in 0..2u32 {
            assert_eq!(got.row(i), emb.row(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let (vocab, emb) = sample();
        let path = std::env::temp_dir().join("pw2v_io_bin.vec");
        save_binary(&path, &vocab, &emb).unwrap();
        let (words, got) = load_binary(&path).unwrap();
        assert_eq!(words.len(), 2);
        for i in 0..2u32 {
            assert_eq!(got.row(i), emb.row(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let (vocab, _) = sample();
        let emb = Embedding::zeros(5, 3);
        let path = std::env::temp_dir().join("pw2v_io_bad.vec");
        assert!(save_text(&path, &vocab, &emb).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_text_rejected() {
        let path = std::env::temp_dir().join("pw2v_io_trunc.vec");
        std::fs::write(&path, "3 2\nw0 1 2\n").unwrap();
        assert!(load_text(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
