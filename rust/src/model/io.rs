//! Model persistence.
//!
//! Vector files, both classic word2vec formats (interoperable with
//! gensim / the original distribution's tools):
//!
//! * text:   header `V D\n`, then `word v1 v2 ... vD\n` per word;
//! * binary: header `V D\n`, then `word<SPACE>` + D little-endian f32s.
//!
//! Plus crash-consistent training CHECKPOINTS for the distributed
//! drivers: a binary snapshot of one rank's full replica (both model
//! matrices) and every piece of mutable trainer state needed to resume
//! the run bit-for-bit — sync round, epoch, reader position, learning-
//! rate progress and RNG state — sealed with an FNV-1a trailer.
//!
//! All writes here go through [`atomic_write`]: bytes land in
//! `<path>.tmp`, are fsync'd, and the tmp is renamed over the target
//! (the PR-3 corpus-cache discipline).  A crash mid-save leaves the
//! previous file intact; a reader never observes a half-written one.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::embedding::Embedding;
use crate::corpus::vocab::Vocab;
use crate::util::fnv::Fnv1a;

/// Write `path` atomically: `write` fills a buffered writer aimed at
/// `<path>.tmp`; on success the tmp is flushed, fsync'd and renamed
/// over `path`.  On any error the target is left untouched (the tmp
/// may remain and is overwritten by the next attempt).
pub fn atomic_write<P: AsRef<Path>>(
    path: P,
    write: impl FnOnce(&mut BufWriter<std::fs::File>) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    let path = path.as_ref();
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    let tmp = std::path::PathBuf::from(os);
    let mut w = BufWriter::with_capacity(1 << 20, std::fs::File::create(&tmp)?);
    write(&mut w)?;
    w.flush()?;
    let f = w.into_inner().map_err(|e| e.into_error())?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// A vocab token the vector writers can store losslessly.  The text
/// format delimits columns with ASCII whitespace and rows with `\n`, and
/// the binary format terminates the word with a single space — an empty
/// token, or one containing ASCII whitespace, would shift every
/// following column on reload (and `load_text`'s re-split could not even
/// tell).  Reject at save time, where the id still names the culprit.
fn check_token(id: u32, word: &str) -> anyhow::Result<()> {
    anyhow::ensure!(!word.is_empty(), "vocab id {id}: empty token cannot be saved");
    anyhow::ensure!(
        !word.bytes().any(|b| b.is_ascii_whitespace()),
        "vocab id {id}: token {word:?} contains whitespace \
         (would corrupt every later column on reload)"
    );
    Ok(())
}

/// Save `M_in` (the word vectors) in text format.
///
/// Rejects tokens that cannot survive the whitespace-delimited format
/// ([`check_token`]) and non-finite values (`NaN`/`inf` have no
/// interoperable text spelling — gensim and the C tools will not read
/// them back) instead of writing a file `load_text` mis-parses.
pub fn save_text<P: AsRef<Path>>(
    path: P,
    vocab: &Vocab,
    emb: &Embedding,
) -> anyhow::Result<()> {
    anyhow::ensure!(vocab.len() == emb.vocab(), "vocab/matrix size mismatch");
    atomic_write(path, |w| {
        writeln!(w, "{} {}", vocab.len(), emb.dim())?;
        for id in 0..vocab.len() as u32 {
            let word = vocab.word(id);
            check_token(id, word)?;
            write!(w, "{word}")?;
            for &x in emb.row(id) {
                anyhow::ensure!(
                    x.is_finite(),
                    "vocab id {id} ({word:?}): non-finite value {x} \
                     does not round-trip through the text format"
                );
                write!(w, " {x}")?;
            }
            writeln!(w)?;
        }
        Ok(())
    })
}

/// Save in binary format.  Values round-trip bit-exactly (little-endian
/// f32), but tokens face the same delimiting rules as the text format
/// ([`check_token`]): the word is terminated by a single space.
pub fn save_binary<P: AsRef<Path>>(
    path: P,
    vocab: &Vocab,
    emb: &Embedding,
) -> anyhow::Result<()> {
    anyhow::ensure!(vocab.len() == emb.vocab(), "vocab/matrix size mismatch");
    atomic_write(path, |w| {
        writeln!(w, "{} {}", vocab.len(), emb.dim())?;
        for id in 0..vocab.len() as u32 {
            let word = vocab.word(id);
            check_token(id, word)?;
            write!(w, "{word} ")?;
            for &x in emb.row(id) {
                w.write_all(&x.to_le_bytes())?;
            }
            writeln!(w)?;
        }
        Ok(())
    })
}

/// Load a text-format vector file: returns `(words, matrix)`.
///
/// Strict about row structure: every data line must hold exactly the
/// word plus `D` parseable values.  A malformed line (token with
/// embedded whitespace, wrong column count, unparseable value) fails
/// loudly with the row, word and column named — never a silent column
/// shift or a bare `ParseFloatError` with no location.
pub fn load_text<P: AsRef<Path>>(path: P) -> anyhow::Result<(Vec<String>, Embedding)> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::with_capacity(1 << 20, f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let (v, d) = parse_header(&header)?;
    let mut words = Vec::with_capacity(v);
    let mut emb = Embedding::zeros(v, d);
    let mut line = String::new();
    for i in 0..v {
        line.clear();
        anyhow::ensure!(r.read_line(&mut line)? > 0, "truncated at row {i}");
        let mut it = line.split_ascii_whitespace();
        let word = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("row {i}: empty vector line"))?;
        let row = emb.row_mut(i as u32);
        for (j, slot) in row.iter_mut().enumerate() {
            let tok = it.next().ok_or_else(|| {
                anyhow::anyhow!("row {i} ({word:?}): expected {d} values, line ends at column {j}")
            })?;
            *slot = tok.parse().map_err(|e| {
                anyhow::anyhow!("row {i} ({word:?}) column {j}: bad value {tok:?} ({e})")
            })?;
        }
        anyhow::ensure!(
            it.next().is_none(),
            "row {i} ({word:?}): more than {d} columns (token with embedded whitespace?)"
        );
        words.push(word.to_string());
    }
    Ok((words, emb))
}

/// Load a binary-format vector file.
pub fn load_binary<P: AsRef<Path>>(
    path: P,
) -> anyhow::Result<(Vec<String>, Embedding)> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::with_capacity(1 << 20, f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let (v, d) = parse_header(&header)?;
    anyhow::ensure!(
        v > 0 && d > 0 && v < u32::MAX as usize && d <= 1 << 20,
        "implausible header {v}x{d}"
    );
    let mut words = Vec::with_capacity(v);
    let mut emb = Embedding::zeros(v, d);
    for i in 0..v {
        // word bytes up to space
        let mut word = Vec::new();
        loop {
            let mut b = [0u8; 1];
            r.read_exact(&mut b)
                .map_err(|e| anyhow::anyhow!("truncated at row {i} word ({e})"))?;
            if b[0] == b' ' {
                break;
            }
            anyhow::ensure!(word.len() < 1 << 16, "unterminated word at row {i}");
            word.push(b[0]);
        }
        words.push(String::from_utf8(word)?);
        let row = emb.row_mut(i as u32);
        let mut buf = vec![0u8; 4 * d];
        r.read_exact(&mut buf)
            .map_err(|e| anyhow::anyhow!("truncated at row {i} vector ({e})"))?;
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = f32::from_le_bytes(buf[4 * j..4 * j + 4].try_into().unwrap());
        }
        // trailing newline
        let mut nl = [0u8; 1];
        r.read_exact(&mut nl)
            .map_err(|e| anyhow::anyhow!("truncated at row {i} terminator ({e})"))?;
    }
    Ok((words, emb))
}

fn parse_header(line: &str) -> anyhow::Result<(usize, usize)> {
    let mut it = line.split_ascii_whitespace();
    let v = it
        .next()
        .ok_or_else(|| anyhow::anyhow!("bad header"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("bad header: not a vector file? ({e})"))?;
    let d = it
        .next()
        .ok_or_else(|| anyhow::anyhow!("bad header"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("bad header: not a vector file? ({e})"))?;
    Ok((v, d))
}

// ---------------------------------------------------------------------------
// Training checkpoints
// ---------------------------------------------------------------------------

const CK_MAGIC: [u8; 4] = *b"PWCK";
const CK_VERSION: u16 = 1;

/// One rank's resumable training snapshot.  Matrices hold `vocab × dim`
/// values — rows are written unpadded, so the on-disk size is
/// independent of the in-memory SIMD stride.
pub struct Checkpoint {
    pub rank: u32,
    pub nranks: u32,
    /// Sync rounds completed when this snapshot was taken (training
    /// resumes at round `round`).
    pub round: u64,
    /// Epoch the corpus reader was in.
    pub epoch: u32,
    /// Sentences already consumed within that epoch (reader replay
    /// position; replay skips sentences WITHOUT consuming trainer RNG).
    pub sentences_in_epoch: u64,
    /// Raw words this rank had processed (throughput accounting).
    pub words_done: u64,
    /// Learning-rate schedule progress (`LrState::words_done`).
    pub lr_words: u64,
    /// Trainer RNG state (`Xoshiro256ss::state`).
    pub rng: [u64; 4],
    /// `TrainConfig::fingerprint() ^ vocab.fingerprint() ^ nranks`; a
    /// resume under different compute-shaping flags is rejected.
    pub fingerprint: u64,
    pub m_in: Embedding,
    pub m_out: Embedding,
}

fn put(w: &mut impl Write, h: &mut Fnv1a, bytes: &[u8]) -> anyhow::Result<()> {
    h.update(bytes);
    w.write_all(bytes)?;
    Ok(())
}

fn take<const N: usize>(r: &mut impl Read, h: &mut Fnv1a) -> anyhow::Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)
        .map_err(|e| anyhow::anyhow!("truncated checkpoint ({e})"))?;
    h.update(&buf);
    Ok(buf)
}

/// Save a checkpoint atomically (tmp + rename + fsync): a crash during
/// the save leaves the previous checkpoint file valid.
pub fn save_checkpoint<P: AsRef<Path>>(path: P, ck: &Checkpoint) -> anyhow::Result<()> {
    anyhow::ensure!(
        ck.m_in.vocab() == ck.m_out.vocab() && ck.m_in.dim() == ck.m_out.dim(),
        "checkpoint matrices disagree on shape"
    );
    atomic_write(path, |w| {
        let mut h = Fnv1a::new();
        put(w, &mut h, &CK_MAGIC)?;
        put(w, &mut h, &CK_VERSION.to_le_bytes())?;
        put(w, &mut h, &ck.rank.to_le_bytes())?;
        put(w, &mut h, &ck.nranks.to_le_bytes())?;
        put(w, &mut h, &ck.round.to_le_bytes())?;
        put(w, &mut h, &ck.epoch.to_le_bytes())?;
        put(w, &mut h, &ck.sentences_in_epoch.to_le_bytes())?;
        put(w, &mut h, &ck.words_done.to_le_bytes())?;
        put(w, &mut h, &ck.lr_words.to_le_bytes())?;
        for s in ck.rng {
            put(w, &mut h, &s.to_le_bytes())?;
        }
        put(w, &mut h, &ck.fingerprint.to_le_bytes())?;
        put(w, &mut h, &(ck.m_in.vocab() as u64).to_le_bytes())?;
        put(w, &mut h, &(ck.m_in.dim() as u64).to_le_bytes())?;
        for emb in [&ck.m_in, &ck.m_out] {
            for id in 0..emb.vocab() as u32 {
                for &x in emb.row(id) {
                    put(w, &mut h, &x.to_le_bytes())?;
                }
            }
        }
        w.write_all(&h.digest().to_le_bytes())?;
        Ok(())
    })
}

/// Load and verify a checkpoint.  Any truncation, bit-rot or wrong-file
/// content fails the magic/version/shape checks or the FNV-1a trailer.
pub fn load_checkpoint<P: AsRef<Path>>(path: P) -> anyhow::Result<Checkpoint> {
    let f = std::fs::File::open(path.as_ref())?;
    let mut r = BufReader::with_capacity(1 << 20, f);
    let mut h = Fnv1a::new();
    let magic: [u8; 4] = take(&mut r, &mut h)?;
    anyhow::ensure!(magic == CK_MAGIC, "not a pw2v checkpoint (bad magic)");
    let version = u16::from_le_bytes(take(&mut r, &mut h)?);
    anyhow::ensure!(
        version == CK_VERSION,
        "checkpoint version {version} (expected {CK_VERSION})"
    );
    let rank = u32::from_le_bytes(take(&mut r, &mut h)?);
    let nranks = u32::from_le_bytes(take(&mut r, &mut h)?);
    let round = u64::from_le_bytes(take(&mut r, &mut h)?);
    let epoch = u32::from_le_bytes(take(&mut r, &mut h)?);
    let sentences_in_epoch = u64::from_le_bytes(take(&mut r, &mut h)?);
    let words_done = u64::from_le_bytes(take(&mut r, &mut h)?);
    let lr_words = u64::from_le_bytes(take(&mut r, &mut h)?);
    let mut rng = [0u64; 4];
    for s in &mut rng {
        *s = u64::from_le_bytes(take(&mut r, &mut h)?);
    }
    let fingerprint = u64::from_le_bytes(take(&mut r, &mut h)?);
    let vocab = u64::from_le_bytes(take(&mut r, &mut h)?) as usize;
    let dim = u64::from_le_bytes(take(&mut r, &mut h)?) as usize;
    anyhow::ensure!(
        rank < nranks && vocab > 0 && dim > 0 && vocab < u32::MAX as usize && dim <= 1 << 20,
        "implausible checkpoint header (rank {rank}/{nranks}, {vocab}x{dim})"
    );
    let mut m_in = Embedding::zeros(vocab, dim);
    let mut m_out = Embedding::zeros(vocab, dim);
    let mut buf = vec![0u8; 4 * dim];
    for emb in [&mut m_in, &mut m_out] {
        for id in 0..vocab as u32 {
            r.read_exact(&mut buf)
                .map_err(|e| anyhow::anyhow!("truncated checkpoint row {id} ({e})"))?;
            h.update(&buf);
            for (j, slot) in emb.row_mut(id).iter_mut().enumerate() {
                *slot = f32::from_le_bytes(buf[4 * j..4 * j + 4].try_into().unwrap());
            }
        }
    }
    let want = h.digest();
    let mut tail = [0u8; 8];
    r.read_exact(&mut tail)
        .map_err(|e| anyhow::anyhow!("truncated checkpoint trailer ({e})"))?;
    let got = u64::from_le_bytes(tail);
    anyhow::ensure!(
        got == want,
        "checkpoint checksum mismatch (corrupt or torn file)"
    );
    Ok(Checkpoint {
        rank,
        nranks,
        round,
        epoch,
        sentences_in_epoch,
        words_done,
        lr_words,
        rng,
        fingerprint,
        m_in,
        m_out,
    })
}

/// The two-slot checkpoint file name for `(rank, slot)`.
///
/// Writers alternate slots (`slot = (round / every) % 2`), so the
/// previous checkpoint survives a crash mid-save of the next one
/// untouched; resume picks the newest slot that loads cleanly.
pub fn checkpoint_slot_path(base: &Path, rank: usize, slot: usize) -> std::path::PathBuf {
    let mut os = base.as_os_str().to_os_string();
    os.push(format!(".rank{rank}.{}", ['a', 'b'][slot % 2]));
    std::path::PathBuf::from(os)
}

/// Newest valid checkpoint across a rank's two slots (None when neither
/// slot loads — e.g. first run, or both torn).
pub fn latest_checkpoint(base: &Path, rank: usize) -> Option<Checkpoint> {
    latest_checkpoint_epoch(base, 0, rank)
}

/// The two-slot checkpoint file name for `(epoch, rank, slot)`.
///
/// Membership epoch 0 (the launch view) keeps the PR-6 layout
/// `<base>.rank{k}.{a,b}` so plain `--resume` stays compatible; healed
/// views (epoch > 0) write to `<base>.e{epoch}.rank{k}.{a,b}` instead —
/// the pre-failure attempt's checkpoints are left INTACT on disk, which
/// is what lets tests (and operators) reconstruct exactly which rollback
/// state a recovery restarted from.
pub fn checkpoint_slot_path_epoch(
    base: &Path,
    epoch: u32,
    rank: usize,
    slot: usize,
) -> std::path::PathBuf {
    if epoch == 0 {
        return checkpoint_slot_path(base, rank, slot);
    }
    let mut os = base.as_os_str().to_os_string();
    os.push(format!(".e{epoch}.rank{rank}.{}", ['a', 'b'][slot % 2]));
    std::path::PathBuf::from(os)
}

/// Newest valid checkpoint across a rank's two slots at a given
/// membership epoch.
pub fn latest_checkpoint_epoch(base: &Path, epoch: u32, rank: usize) -> Option<Checkpoint> {
    let mut best: Option<Checkpoint> = None;
    for slot in 0..2 {
        if let Ok(ck) = load_checkpoint(checkpoint_slot_path_epoch(base, epoch, rank, slot)) {
            if best.as_ref().map_or(true, |b| ck.round > b.round) {
                best = Some(ck);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Vocab, Embedding) {
        let vocab = Vocab::build("b a a".split_whitespace(), 1);
        let mut emb = Embedding::zeros(2, 3);
        emb.row_mut(0).copy_from_slice(&[1.5, -2.0, 0.25]);
        emb.row_mut(1).copy_from_slice(&[0.0, 3.0, -0.125]);
        (vocab, emb)
    }

    #[test]
    fn text_roundtrip() {
        let (vocab, emb) = sample();
        let path = std::env::temp_dir().join("pw2v_io_text.vec");
        save_text(&path, &vocab, &emb).unwrap();
        let (words, got) = load_text(&path).unwrap();
        assert_eq!(words, vec!["a".to_string(), "b".to_string()]);
        for i in 0..2u32 {
            assert_eq!(got.row(i), emb.row(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn binary_roundtrip() {
        let (vocab, emb) = sample();
        let path = std::env::temp_dir().join("pw2v_io_bin.vec");
        save_binary(&path, &vocab, &emb).unwrap();
        let (words, got) = load_binary(&path).unwrap();
        assert_eq!(words, vec!["a".to_string(), "b".to_string()]);
        for i in 0..2u32 {
            assert_eq!(got.row(i), emb.row(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let (vocab, _) = sample();
        let emb = Embedding::zeros(5, 3);
        let path = std::env::temp_dir().join("pw2v_io_bad.vec");
        assert!(save_text(&path, &vocab, &emb).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_text_rejected() {
        let path = std::env::temp_dir().join("pw2v_io_trunc.vec");
        std::fs::write(&path, "3 2\nw0 1 2\n").unwrap();
        assert!(load_text(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    fn vocab_of(words: &[&str]) -> Vocab {
        // Descending counts pin ids in the given order.
        let counts: std::collections::HashMap<String, u64> = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.to_string(), (words.len() - i) as u64))
            .collect();
        Vocab::from_counts(counts, 1)
    }

    #[test]
    fn hostile_tokens_rejected_at_save_never_corrupt_a_roundtrip() {
        let dir = std::env::temp_dir();
        for (name, bad) in [
            ("space", "has space"),
            ("tab", "has\ttab"),
            ("newline", "has\nnewline"),
        ] {
            let vocab = vocab_of(&["fine", bad]);
            let emb = Embedding::zeros(2, 3);
            let path = dir.join(format!("pw2v_io_hostile_{name}.vec"));
            std::fs::remove_file(&path).ok();
            let err = save_text(&path, &vocab, &emb).unwrap_err().to_string();
            assert!(err.contains("whitespace"), "unhelpful error: {err}");
            let err = save_binary(&path, &vocab, &emb).unwrap_err().to_string();
            assert!(err.contains("whitespace"), "unhelpful error: {err}");
            // The failed save must not leave a file a later load could read.
            assert!(!path.exists(), "{name}: refused save left {path:?}");
            let mut tmp = path.clone().into_os_string();
            tmp.push(".tmp");
            std::fs::remove_file(tmp).ok();
        }
        // Empty token: same contract.
        let vocab = vocab_of(&["fine", ""]);
        let emb = Embedding::zeros(2, 3);
        let path = dir.join("pw2v_io_hostile_empty.vec");
        let err = save_text(&path, &vocab, &emb).unwrap_err().to_string();
        assert!(err.contains("empty token"), "unhelpful error: {err}");
        // A well-formed vocab with odd-but-legal tokens still round-trips.
        let vocab = vocab_of(&["naïve", "comma,token"]);
        let mut emb = Embedding::zeros(2, 2);
        emb.row_mut(0).copy_from_slice(&[1.0, -2.5]);
        emb.row_mut(1).copy_from_slice(&[0.125, 3.0]);
        let path = dir.join("pw2v_io_hostile_ok.vec");
        save_text(&path, &vocab, &emb).unwrap();
        let (words, got) = load_text(&path).unwrap();
        assert_eq!(words, vec!["naïve".to_string(), "comma,token".to_string()]);
        for i in 0..2u32 {
            assert_eq!(got.row(i), emb.row(i));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn nonfinite_values_rejected_at_text_save() {
        let vocab = vocab_of(&["a", "b"]);
        let mut emb = Embedding::zeros(2, 2);
        emb.row_mut(1)[0] = f32::NAN;
        let path = std::env::temp_dir().join("pw2v_io_nan.vec");
        let err = save_text(&path, &vocab, &emb).unwrap_err().to_string();
        assert!(err.contains("non-finite"), "unhelpful error: {err}");
        emb.row_mut(1)[0] = f32::INFINITY;
        assert!(save_text(&path, &vocab, &emb).is_err());
        // Binary stores raw bits: non-finite survives there losslessly.
        save_binary(&path, &vocab, &emb).unwrap();
        let (_, got) = load_binary(&path).unwrap();
        assert_eq!(got.row(1)[0], f32::INFINITY);
        std::fs::remove_file(&path).ok();
        let mut tmp = path.into_os_string();
        tmp.push(".tmp");
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn malformed_text_rows_fail_with_location_context() {
        let dir = std::env::temp_dir();
        // Unparseable value: error names row, word and column.
        let path = dir.join("pw2v_io_badval.vec");
        std::fs::write(&path, "2 2\nw0 1 2\nw1 3 oops\n").unwrap();
        let err = load_text(&path).unwrap_err().to_string();
        assert!(
            err.contains("row 1") && err.contains("w1") && err.contains("oops"),
            "unhelpful error: {err}"
        );
        // Extra columns (the signature of an embedded-whitespace token)
        // must be rejected, not silently dropped.
        std::fs::write(&path, "2 2\nw0 1 2\nbad token 3 4\n").unwrap();
        let err = load_text(&path).unwrap_err().to_string();
        assert!(err.contains("more than 2 columns"), "unhelpful error: {err}");
        // Short row: the missing column is named.
        std::fs::write(&path, "2 2\nw0 1 2\nw1 3\n").unwrap();
        let err = load_text(&path).unwrap_err().to_string();
        assert!(err.contains("ends at column 1"), "unhelpful error: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_binary_rejected_with_clear_error() {
        let path = std::env::temp_dir().join("pw2v_io_garbage.vec");
        std::fs::write(&path, b"this is not a vector file at all").unwrap();
        let err = load_binary(&path).unwrap_err().to_string();
        assert!(err.contains("header"), "unhelpful error: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_binary_rejected() {
        let (vocab, emb) = sample();
        let path = std::env::temp_dir().join("pw2v_io_bintrunc.vec");
        save_binary(&path, &vocab, &emb).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let err = load_binary(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "unhelpful error: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn atomic_write_leaves_no_tmp_and_survives_failed_write() {
        let dir = std::env::temp_dir();
        let path = dir.join("pw2v_io_atomic.txt");
        atomic_write(&path, |w| {
            w.write_all(b"first")?;
            Ok(())
        })
        .unwrap();
        // A failing writer must not clobber the existing file.
        assert!(atomic_write(&path, |w| {
            w.write_all(b"half")?;
            anyhow::bail!("simulated failure")
        })
        .is_err());
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        std::fs::remove_file(&path).ok();
        let mut tmp = path.into_os_string();
        tmp.push(".tmp");
        std::fs::remove_file(tmp).ok();
    }

    fn sample_checkpoint() -> Checkpoint {
        let mut m_in = Embedding::zeros(5, 4);
        let mut m_out = Embedding::zeros(5, 4);
        for id in 0..5u32 {
            for (j, x) in m_in.row_mut(id).iter_mut().enumerate() {
                *x = id as f32 + j as f32 * 0.25;
            }
            for (j, x) in m_out.row_mut(id).iter_mut().enumerate() {
                *x = -(id as f32) - j as f32 * 0.5;
            }
        }
        Checkpoint {
            rank: 1,
            nranks: 3,
            round: 17,
            epoch: 2,
            sentences_in_epoch: 4242,
            words_done: 123_456,
            lr_words: 120_000,
            rng: [1, 2, 3, 4],
            fingerprint: 0xDEAD_BEEF_CAFE_F00D,
            m_in,
            m_out,
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let path = std::env::temp_dir().join("pw2v_ck_rt.ck");
        let ck = sample_checkpoint();
        save_checkpoint(&path, &ck).unwrap();
        let got = load_checkpoint(&path).unwrap();
        assert_eq!(got.rank, ck.rank);
        assert_eq!(got.nranks, ck.nranks);
        assert_eq!(got.round, ck.round);
        assert_eq!(got.epoch, ck.epoch);
        assert_eq!(got.sentences_in_epoch, ck.sentences_in_epoch);
        assert_eq!(got.words_done, ck.words_done);
        assert_eq!(got.lr_words, ck.lr_words);
        assert_eq!(got.rng, ck.rng);
        assert_eq!(got.fingerprint, ck.fingerprint);
        for id in 0..5u32 {
            assert_eq!(got.m_in.row(id), ck.m_in.row(id));
            assert_eq!(got.m_out.row(id), ck.m_out.row(id));
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_rejects_corruption_and_truncation() {
        let path = std::env::temp_dir().join("pw2v_ck_bad.ck");
        save_checkpoint(&path, &sample_checkpoint()).unwrap();
        let full = std::fs::read(&path).unwrap();

        // Bit flip in a model row: checksum must catch it.
        let mut flipped = full.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        std::fs::write(&path, &flipped).unwrap();
        let err = load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unhelpful error: {err}");

        // Truncation (torn write): must be rejected, not misread.
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        assert!(load_checkpoint(&path).is_err());

        // Wrong magic.
        let mut wrong = full.clone();
        wrong[..4].copy_from_slice(b"NOPE");
        std::fs::write(&path, &wrong).unwrap();
        let err = load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("magic"), "unhelpful error: {err}");

        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn latest_checkpoint_picks_newest_valid_slot() {
        let base = std::env::temp_dir().join("pw2v_ck_slots");
        let mut ck = sample_checkpoint();
        ck.round = 10;
        save_checkpoint(checkpoint_slot_path(&base, 1, 0), &ck).unwrap();
        ck.round = 20;
        save_checkpoint(checkpoint_slot_path(&base, 1, 1), &ck).unwrap();
        assert_eq!(latest_checkpoint(&base, 1).unwrap().round, 20);

        // Tear the newer slot: resume falls back to the older one.
        let newer = checkpoint_slot_path(&base, 1, 1);
        let bytes = std::fs::read(&newer).unwrap();
        std::fs::write(&newer, &bytes[..bytes.len() / 2]).unwrap();
        assert_eq!(latest_checkpoint(&base, 1).unwrap().round, 10);

        // No slots at all.
        assert!(latest_checkpoint(&base, 0).is_none());

        for slot in 0..2 {
            std::fs::remove_file(checkpoint_slot_path(&base, 1, slot)).ok();
        }
    }

    #[test]
    fn epoch_slot_paths_keep_attempts_separate() {
        let base = std::path::Path::new("/tmp/ckbase");
        // Epoch 0 must stay the PR-6 layout (plain --resume compatibility).
        assert_eq!(
            checkpoint_slot_path_epoch(base, 0, 2, 1),
            checkpoint_slot_path(base, 2, 1)
        );
        assert_eq!(
            checkpoint_slot_path_epoch(base, 1, 0, 0),
            std::path::PathBuf::from("/tmp/ckbase.e1.rank0.a")
        );
        assert_eq!(
            checkpoint_slot_path_epoch(base, 3, 2, 1),
            std::path::PathBuf::from("/tmp/ckbase.e3.rank2.b")
        );

        let dir = std::env::temp_dir().join("pw2v_ck_epochs");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("ck");
        let mut ck = sample_checkpoint();
        ck.round = 5;
        save_checkpoint(checkpoint_slot_path_epoch(&base, 0, 1, 0), &ck).unwrap();
        ck.round = 9;
        save_checkpoint(checkpoint_slot_path_epoch(&base, 1, 1, 0), &ck).unwrap();
        // Each epoch's slots are independent files.
        assert_eq!(latest_checkpoint_epoch(&base, 0, 1).unwrap().round, 5);
        assert_eq!(latest_checkpoint_epoch(&base, 1, 1).unwrap().round, 9);
        assert!(latest_checkpoint_epoch(&base, 2, 1).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
