//! Dense row-major `[V, D]` embedding matrix with cache-line-aligned rows.
//!
//! Alignment matters for the paper's argument: false sharing between
//! adjacent rows is part of the Hogwild coherence traffic (Sec. III-A), so
//! rows are padded to 64-byte boundaries (`stride >= dim`), matching what a
//! careful production implementation does.

use crate::util::rng::Xoshiro256ss;

pub const CACHE_LINE: usize = 64;

/// One row of the standard word2vec init stream — the ONLY definition of
/// the init distribution.  `Embedding::uniform_init` (main-thread init)
/// and `SharedModel::first_touch_init` (pinned in-thread init for
/// NUMA-local dist replicas) both consume the same sequential RNG through
/// here, which is what makes their bitwise-equality contract structural
/// rather than a copy kept in sync by hand.
#[inline]
pub(crate) fn uniform_init_row(row: &mut [f32], dim: usize, rng: &mut Xoshiro256ss) {
    for x in row.iter_mut() {
        *x = (rng.next_f32() - 0.5) / dim as f32;
    }
}
const F32_PER_LINE: usize = CACHE_LINE / std::mem::size_of::<f32>();

#[derive(Clone, Debug)]
pub struct Embedding {
    vocab: usize,
    dim: usize,
    /// Row stride in f32 elements (dim rounded up to the cache line).
    stride: usize,
    data: Vec<f32>,
}

impl Embedding {
    /// All-zeros matrix (the original initialises `M_out` to zero).
    pub fn zeros(vocab: usize, dim: usize) -> Self {
        let stride = crate::util::round_up(dim.max(1), F32_PER_LINE);
        Self {
            vocab,
            dim,
            stride,
            data: vec![0.0; vocab * stride],
        }
    }

    /// Uniform init in `[-0.5/dim, 0.5/dim)` (the original's `M_in` init).
    pub fn uniform_init(vocab: usize, dim: usize, seed: u64) -> Self {
        let mut e = Self::zeros(vocab, dim);
        let mut rng = Xoshiro256ss::new(seed);
        for w in 0..vocab {
            uniform_init_row(e.row_mut(w as u32), dim, &mut rng);
        }
        e
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    #[inline]
    pub fn row(&self, w: u32) -> &[f32] {
        let o = w as usize * self.stride;
        &self.data[o..o + self.dim]
    }

    #[inline]
    pub fn row_mut(&mut self, w: u32) -> &mut [f32] {
        let o = w as usize * self.stride;
        &mut self.data[o..o + self.dim]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Raw base pointer (for the Hogwild wrapper).
    pub(crate) fn as_ptr(&self) -> *const f32 {
        self.data.as_ptr()
    }

    /// Racy mutable row view — the Hogwild wrappers' SINGLE audited
    /// pointer-math site (both the flat and the NUMA-sharded store
    /// route every row access through here).
    ///
    /// # Safety
    /// Caller upholds the Hogwild contract (`model::hogwild` docs): the
    /// embedding outlives the borrow and racy same-row access is the
    /// algorithm's admitted approximation.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn racy_row(&self, row: u32) -> &mut [f32] {
        let o = row as usize * self.stride;
        std::slice::from_raw_parts_mut(
            (self.data.as_ptr() as *mut f32).add(o),
            self.dim,
        )
    }

    /// L2-normalised copy of a row (for cosine evaluation).
    pub fn unit_row(&self, w: u32) -> Vec<f32> {
        let r = self.row(w);
        let n = r.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
        r.iter().map(|x| x / n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_cache_aligned() {
        for dim in [1usize, 15, 16, 17, 100, 300] {
            let e = Embedding::zeros(10, dim);
            assert_eq!(e.stride() % F32_PER_LINE, 0, "dim={dim}");
            assert!(e.stride() >= dim);
            // Base allocation of Vec<f32> is at least 4-aligned; row offsets
            // are multiples of 16 f32s = 64 bytes apart.
            let a = e.row(3).as_ptr() as usize;
            let b = e.row(4).as_ptr() as usize;
            assert_eq!((b - a) % CACHE_LINE, 0);
        }
    }

    #[test]
    fn uniform_init_range_and_determinism() {
        let a = Embedding::uniform_init(100, 50, 7);
        let b = Embedding::uniform_init(100, 50, 7);
        assert_eq!(a.data(), b.data());
        let bound = 0.5 / 50.0;
        for w in 0..100u32 {
            for &x in a.row(w) {
                assert!(x >= -bound && x < bound);
            }
        }
        // Not all zero.
        assert!(a.data().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn row_mut_isolated() {
        let mut e = Embedding::zeros(4, 3);
        e.row_mut(1).copy_from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(e.row(0), &[0.0, 0.0, 0.0]);
        assert_eq!(e.row(1), &[1.0, 2.0, 3.0]);
        assert_eq!(e.row(2), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn unit_row_normalises() {
        let mut e = Embedding::zeros(1, 4);
        e.row_mut(0).copy_from_slice(&[3.0, 0.0, 4.0, 0.0]);
        let u = e.unit_row(0);
        assert!((u[0] - 0.6).abs() < 1e-6);
        assert!((u[2] - 0.8).abs() < 1e-6);
    }
}
