//! Model substrate: the `[V, D]` embedding matrices `M_in`/`M_out`, their
//! lock-free Hogwild sharing wrappers (flat and NUMA-sharded), and
//! word2vec-format persistence.

pub mod embedding;
pub mod hogwild;
pub mod io;

pub use embedding::Embedding;
pub use hogwild::{
    reset_row_access_stats, row_access_stats, set_access_node, ModelRef,
    NumaModel, ShardMap, SharedModel,
};
