//! The training-family subcommands: `train` (shared-memory) and
//! `train-dist` (replica threads or a multi-process TCP ring).

use std::path::PathBuf;

use crate::config::TrainConfig;
use crate::corpus::vocab::Vocab;
use crate::dist::{
    train_distributed, train_tcp_ring, CheckpointPolicy, DistConfig, FaultSpec,
    NetConfig, OnFailure, RingSpec, SyncPolicy,
};
use crate::model::{io as model_io, SharedModel};
use crate::train;
use crate::util::args::Args;
use crate::util::si;

use super::common;

pub const TRAIN_HELP: &str = "\
USAGE: pw2v train --corpus corpus.txt [--out vectors.txt] [shared flags]
       pw2v <corpus>                  (compatibility alias)

Shared-memory training.  --corpus-cache auto encodes <corpus>.pw2v.u32
once and trains from the u32 cache: no per-epoch re-tokenization.
--numa auto shards M_in/M_out across NUMA nodes and pins workers so
Hogwild scatters stay socket-local; --route owner additionally steers
each hot-target window to the worker on the target row's home node —
bounded mailboxes, local fallback under backpressure.

";

pub const DIST_HELP: &str = "\
USAGE: pw2v train-dist --corpus corpus.txt --nodes N
         [--sync-interval W --policy sub|full --no-lr-scaling]
         [--out vectors.txt]
         [--dist threads|tcp:RANK@ADDR0,ADDR1,...]
         [--checkpoint BASE --checkpoint-every ROUNDS --resume]
         [--net-timeout-ms MS --heartbeat-ms MS --connect-timeout-ms MS]
         [--on-failure abort|shrink|rejoin --rejoin-grace-ms MS]
         [shared flags]

Distributed data-parallel training.  --numa auto pins each replica to a
NUMA node and first-touches it there — one replica per socket keeps
training traffic node-local; --route is accepted for config parity but
is a no-op here: each replica is one worker, so every window already
processes on its home node.

--dist tcp:... runs THIS process as one rank of a TCP ring — launch one
process per address, each with its own rank; --nodes is implied by the
address list.  Full-sync rings are bitwise-identical to thread mode.
--checkpoint writes two-slot crash-consistent snapshots at
BASE.rankK.{a,b} every ROUNDS sync rounds; --resume continues from the
newest round every rank can load.

--on-failure shrink (needs --checkpoint) self-heals on a peer failure:
survivors regroup at a new membership epoch, roll back to the newest
checkpoint round all of them hold, re-shard over the smaller ring and
continue; rejoin additionally holds the regroup open for
--rejoin-grace-ms so a promptly respawned rank is re-admitted; abort
(default) fails the whole run fast.  Frame deadlines adapt to measured
round time (EWMA); --net-timeout-ms is the floor.  PW2V_FAULT injects
deterministic faults (kill-after=N | torn-frame=N | stall-after=N |
panic-replica=I | kill-epoch=E | wedge-regroup=E | respawn-after=MS)
for the fault suite.

";

pub fn train(a: &Args) -> anyhow::Result<()> {
    let corpus = common::corpus_arg(a)?;
    let out: Option<String> = a.opt("out")?;
    let cfg = common::train_config(a, TrainConfig::default())?;
    a.check_unknown()?;

    eprintln!("building vocabulary ...");
    let vocab = Vocab::build_from_file(&corpus, cfg.min_count)?;
    eprintln!(
        "vocab {} words, corpus {} tokens",
        vocab.len(),
        vocab.total_words()
    );
    let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
    eprintln!(
        "training: backend={} threads={} dim={} epochs={} simd={} kernel={} \
         reuse={} sigmoid={} corpus-cache={} numa={} route={}",
        cfg.backend,
        cfg.threads,
        cfg.dim,
        cfg.epochs,
        cfg.simd,
        cfg.kernel,
        cfg.reuse,
        cfg.sigmoid_mode,
        cfg.corpus_cache,
        cfg.numa,
        cfg.route
    );
    let outcome = train::train(&cfg, &corpus, &vocab, &model)?;
    let snap = outcome.snapshot;
    eprintln!(
        "done: {} words in {:.1}s = {} words/sec ({} windows, {} calls)",
        snap.words,
        snap.secs,
        si(snap.words_per_sec()),
        snap.windows,
        snap.calls
    );
    if let Some(p) = out {
        model_io::save_text(&p, &vocab, model.m_in())?;
        eprintln!("vectors saved to {p}");
    }
    Ok(())
}

pub fn train_dist(a: &Args) -> anyhow::Result<()> {
    let corpus = common::corpus_arg(a)?;
    let out: Option<String> = a.opt("out")?;
    let cfg = common::train_config(a, TrainConfig::default())?;

    // Transport: in-process replica threads (default) or one rank of a
    // multi-process TCP ring.
    let transport: String = a.get("dist", "threads".to_string())?;
    let ring = match transport.as_str() {
        "threads" => None,
        spec if spec.starts_with("tcp:") => Some(RingSpec::parse(spec)?),
        other => anyhow::bail!("unknown transport '{other}' (threads|tcp:RANK@ADDRS)"),
    };
    let nodes: usize = match &ring {
        Some(r) => {
            anyhow::ensure!(
                a.opt::<usize>("nodes")?.map_or(true, |n| n == r.nranks()),
                "--nodes disagrees with the tcp ring's address count"
            );
            r.nranks()
        }
        None => a.get("nodes", 2)?,
    };

    let mut dist = DistConfig::for_nodes(nodes);
    dist.sync_interval = a.get("sync-interval", dist.sync_interval)?;
    match a.opt::<String>("policy")?.as_deref() {
        Some("full") => dist.policy = SyncPolicy::Full,
        Some("sub") | None => {}
        Some(p) => anyhow::bail!("unknown policy '{p}' (sub|full)"),
    }
    if a.flag("no-lr-scaling") {
        dist.scale_lr = false;
    }
    if let Some(p) = a.opt::<String>("on-failure")? {
        dist.on_failure = p.parse::<OnFailure>()?;
        anyhow::ensure!(
            ring.is_some() || dist.on_failure == OnFailure::Abort,
            "--on-failure shrink/rejoin needs the tcp transport \
             (thread mode always fails fast)"
        );
    }
    // Thread-mode fault injection (TCP wire faults are read from the
    // environment by the transport itself).
    dist.fault = FaultSpec::from_env()
        .map_err(|e| anyhow::anyhow!("PW2V_FAULT: {e:#}"))?;

    let defaults = NetConfig::default();
    let net = NetConfig {
        connect_timeout_ms: a.get("connect-timeout-ms", defaults.connect_timeout_ms)?,
        io_timeout_ms: a.get("net-timeout-ms", defaults.io_timeout_ms)?,
        heartbeat_ms: a.get("heartbeat-ms", defaults.heartbeat_ms)?,
        rejoin_grace_ms: a.get("rejoin-grace-ms", defaults.rejoin_grace_ms)?,
    };
    let ckpt = CheckpointPolicy {
        base: a.opt::<String>("checkpoint")?.map(PathBuf::from),
        every: a.get("checkpoint-every", 8u64)?,
        resume: a.flag("resume"),
    };
    a.check_unknown()?;

    let vocab = Vocab::build_from_file(&corpus, cfg.min_count)?;
    let outcome = match &ring {
        None => {
            eprintln!(
                "distributed training: {} replica threads, sync every {} words, \
                 vocab {}, numa={} route={}",
                nodes,
                dist.sync_interval,
                vocab.len(),
                cfg.numa,
                cfg.route
            );
            train_distributed(&cfg, &dist, &corpus, &vocab)?
        }
        Some(spec) => {
            eprintln!(
                "distributed training: rank {}/{} on tcp ring, sync every {} \
                 words, vocab {}, checkpoint={}, on-failure={:?}",
                spec.rank,
                nodes,
                dist.sync_interval,
                vocab.len(),
                ckpt.base
                    .as_deref()
                    .map(|p| p.display().to_string())
                    .unwrap_or_else(|| "off".into()),
                dist.on_failure,
            );
            train_tcp_ring(&cfg, &dist, spec, &net, &ckpt, &corpus, &vocab)?
        }
    };
    eprintln!(
        "done: {} words in {:.1}s = {} words/sec aggregate",
        outcome.words,
        outcome.secs,
        si(outcome.words as f64 / outcome.secs.max(1e-9))
    );
    for (i, st) in outcome.sync_stats.iter().enumerate() {
        eprintln!(
            "  node {i}: {} rounds, {} rows synced, {} wire bytes",
            st.rounds,
            st.rows_synced,
            si(st.wire_bytes as f64)
        );
    }
    if let Some(n) = &outcome.net {
        eprintln!(
            "  ring: {} frames / {} bytes sent ({} slice bytes), \
             {} frames / {} bytes recv, {} heartbeats",
            n.frames_sent,
            si(n.bytes_sent as f64),
            si(n.slice_bytes_sent as f64),
            n.frames_recv,
            si(n.bytes_recv as f64),
            n.heartbeats_sent
        );
    }
    if let Some(p) = out {
        model_io::save_text(&p, &vocab, outcome.model.m_in())?;
        eprintln!("vectors saved to {p}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::common::SHARED_FLAGS;

    #[test]
    fn help_texts_reference_the_shared_flag_table_keys() {
        for key in ["--corpus", "shared flags"] {
            assert!(TRAIN_HELP.contains(key), "train help lacks {key}");
            assert!(DIST_HELP.contains(key), "dist help lacks {key}");
        }
        for key in [
            "--simd",
            "--reuse",
            "avx512",
            "--corpus-cache",
            "--numa",
            "--vocab-reserve",
        ] {
            assert!(SHARED_FLAGS.contains(key), "shared table lacks {key}");
        }
    }
}
