//! The `serve` subcommand: answer topk/analogy/stats queries over a
//! trained model, with optional `--watch` hot-swapping of the row store.

use std::path::Path;

use crate::config::QuantMode;
use crate::linalg::simd::{self, SimdMode};
use crate::model::io as model_io;
use crate::serve::{run_listen, run_stdio, RowStore, ServeEngine, StoreWatcher};
use crate::util::args::Args;

pub const HELP: &str = "\
USAGE: pw2v serve --vectors vectors.txt | --store model.rst
         [--save-store model.rst --quant off|int8
          --simd auto|avx512|avx2|scalar --listen HOST:PORT --watch]

Line-delimited JSON over stdin/stdout, or TCP with --listen.
Requests (one JSON response line each):
  {\"op\":\"topk\",\"word\":W,\"k\":K}
  {\"op\":\"analogy\",\"a\":A,\"b\":B,\"c\":C,\"k\":K}
  {\"op\":\"stats\"}                  -> vocab/dim/quant/generation

--save-store writes the mmap-able binary row store (then serves from
it); --store opens one directly — O(header+vocab) startup, no float
parsing.  --quant int8 scans per-row symmetric int8 codes: ~4x less
scan bandwidth, recall gated in CI.  --watch (needs --store) polls the
store file between request lines and hot-swaps to newer
generation-stamped exports (`stream --store` writes them) without
dropping the connection.
";

pub fn serve(a: &Args) -> anyhow::Result<()> {
    let vectors: Option<String> = a.opt("vectors")?;
    let store_path: Option<String> = a.opt("store")?;
    let save_store: Option<String> = a.opt("save-store")?;
    let quant: QuantMode = a.get("quant", QuantMode::default())?;
    let simd_mode: SimdMode = a.get("simd", SimdMode::default())?;
    let listen: Option<String> = a.opt("listen")?;
    let watch = a.flag("watch");
    a.check_unknown()?;

    let level = simd::configure(simd_mode)?;
    let store = match (&vectors, &store_path) {
        (Some(v), None) => {
            let (words, emb) = model_io::load_text(v)?;
            let st = RowStore::from_model(words, &emb)?;
            eprintln!(
                "serve: loaded {} vectors of dim {} from {v}",
                st.n_rows(),
                st.dim()
            );
            st
        }
        (None, Some(p)) => {
            let st = RowStore::open(Path::new(p))?;
            eprintln!(
                "serve: opened row store {p} ({} rows, dim {}, generation {})",
                st.n_rows(),
                st.dim(),
                st.generation()
            );
            st
        }
        _ => anyhow::bail!("serve needs exactly one of --vectors or --store"),
    };
    if let Some(p) = &save_store {
        store.save(Path::new(p))?;
        eprintln!("serve: row store saved to {p}");
    }
    let mut watcher = match (watch, &store_path) {
        (false, _) => None,
        (true, Some(p)) => Some(StoreWatcher::new(Path::new(p))),
        (true, None) => {
            anyhow::bail!("--watch needs --store (a file to poll for new exports)")
        }
    };
    let mut eng = ServeEngine::from_store(store, quant)?;
    eprintln!("serve: simd={level:?} quant={quant} watch={watch}");
    match listen {
        Some(addr) => run_listen(&mut eng, &addr, watcher.as_mut()),
        None => run_stdio(&mut eng, watcher.as_mut()),
    }
}
