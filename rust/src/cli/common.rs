//! Flag plumbing shared by every subcommand.
//!
//! The training-family commands (`train`, `train-dist`, `stream`)
//! accept the same execution knobs; [`SHARED_FLAGS`] is the one help
//! block describing them (appended to each command's usage) and
//! [`train_config`] is the one loader (defaults → `--config` file →
//! CLI overrides), so a flag added to `TrainConfig::apply_args` shows
//! up everywhere at once.

use std::path::PathBuf;

use crate::config::TrainConfig;
use crate::util::args::Args;

/// The shared execution-knob table, one help block for all trainers.
pub const SHARED_FLAGS: &str = "\
shared training flags (train / train-dist / stream):
  --config FILE               key=value file applied before CLI overrides
  --dim D --window W --negative N --sample S --lr LR --min-count C
  --epochs E --threads T --seed S --batch B --superbatch SB
  --backend scalar|bidmach|gemm|pjrt
  --kernel auto|fused|gemm3   fused Pallas-style kernel vs 3-GEMM reference
  --sigmoid exact|table       exact sigmoid or the C tool's 1000-slot table
  --simd auto|avx512|avx2|scalar  SIMD dispatch for kernels and serving scans
  --reuse off|window|sentence negative-sample lifetime (gemm backend)
  --corpus-cache off|auto|P   reuse the .pw2v.u32 encoded-corpus cache
  --numa off|auto|NODES       NUMA-aware model placement + worker pinning
  --route off|owner|head=K    hot-target window routing (train only)
  --vocab-reserve N           pre-allocate N rows for streaming admission
";

/// Defaults → optional `--config` file → CLI overrides, in that order.
/// `base` lets a command pre-seed command-specific defaults (e.g.
/// `stream` pins `backend=gemm threads=1 epochs=1`) that explicit flags
/// still override.
pub fn train_config(a: &Args, base: TrainConfig) -> anyhow::Result<TrainConfig> {
    let mut cfg = base;
    if let Some(f) = a.opt::<String>("config")? {
        cfg.load_file(f)?;
    }
    cfg.apply_args(a)?;
    Ok(cfg)
}

/// The corpus path: `--corpus PATH`, or the first positional (which is
/// how the bare `pw2v <corpus>` compatibility alias delivers it).
pub fn corpus_arg(a: &Args) -> anyhow::Result<PathBuf> {
    if let Some(c) = a.opt::<String>("corpus")? {
        return Ok(PathBuf::from(c));
    }
    match a.positional().first() {
        Some(p) => Ok(PathBuf::from(p)),
        None => anyhow::bail!("missing --corpus (or bare `pw2v <corpus>`)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn corpus_comes_from_flag_or_positional() {
        assert_eq!(
            corpus_arg(&args("--corpus a.txt")).unwrap(),
            PathBuf::from("a.txt")
        );
        assert_eq!(
            corpus_arg(&args("b.txt --dim 8")).unwrap(),
            PathBuf::from("b.txt")
        );
        assert!(corpus_arg(&args("--dim 8")).is_err());
    }

    #[test]
    fn explicit_flags_override_the_preseeded_base() {
        let mut base = TrainConfig::test_tiny();
        base.threads = 1;
        let cfg = train_config(&args("--threads 3"), base).unwrap();
        assert_eq!(cfg.threads, 3);
        let cfg2 = train_config(&args(""), {
            let mut b = TrainConfig::test_tiny();
            b.threads = 1;
            b
        })
        .unwrap();
        assert_eq!(cfg2.threads, 1);
    }
}
