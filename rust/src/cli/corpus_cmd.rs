//! Corpus-side subcommands: `gen-corpus` (synthetic latent-model
//! corpus + eval sets) and `encode` (pre-build the `.pw2v.u32` cache).

use std::path::PathBuf;

use crate::config::TrainConfig;
use crate::corpus::encoded::EncodedCorpus;
use crate::corpus::synthetic::{LatentModel, SyntheticConfig};
use crate::corpus::vocab::Vocab;
use crate::eval;
use crate::util::args::Args;
use crate::util::si;

use super::common;

pub const GEN_HELP: &str = "\
USAGE: pw2v gen-corpus --out corpus.txt
         [--tokens N --vocab V --clusters C --seed S]
         [--simset sim.tsv --anaset ana.txt]

Generate a synthetic corpus from a latent cluster model, plus matching
similarity/analogy evaluation sets whose ground truth the model knows.
";

pub const ENCODE_HELP: &str = "\
USAGE: pw2v encode --corpus corpus.txt [--cache PATH] [--min-count C]

Pre-build the .pw2v.u32 encoded-corpus cache (tokenized sentences as
vocab ids).  Training with --corpus-cache auto finds it at
<corpus>.pw2v.u32 — the default --cache — and skips per-epoch
re-tokenization; `stream` adopts and appends to the same file.
";

pub fn gen_corpus(a: &Args) -> anyhow::Result<()> {
    let out: String = a.required("out")?;
    let mut scfg = SyntheticConfig::default();
    scfg.tokens = a.get("tokens", scfg.tokens)?;
    scfg.vocab = a.get("vocab", scfg.vocab)?;
    scfg.clusters = a.get("clusters", scfg.clusters)?;
    scfg.seed = a.get("seed", scfg.seed)?;
    let simset: Option<String> = a.opt("simset")?;
    let anaset: Option<String> = a.opt("anaset")?;
    a.check_unknown()?;

    eprintln!(
        "generating {} tokens, vocab {}, {} clusters ...",
        scfg.tokens, scfg.vocab, scfg.clusters
    );
    let lm = LatentModel::new(scfg);
    let n = lm.write_corpus(&out)?;
    eprintln!("wrote {n} tokens to {out}");
    if let Some(p) = simset {
        let set = eval::gen_similarity_set(&lm, 350, 7);
        eval::datasets::save_similarity_set(&p, &set)?;
        eprintln!("wrote {} similarity pairs to {p}", set.len());
    }
    if let Some(p) = anaset {
        let set = eval::gen_analogy_set(&lm);
        eval::datasets::save_analogy_set(&p, &set)?;
        eprintln!("wrote {} analogy questions to {p}", set.len());
    }
    Ok(())
}

pub fn encode(a: &Args) -> anyhow::Result<()> {
    let corpus = common::corpus_arg(a)?;
    let min_count: u64 = a.get("min-count", TrainConfig::default().min_count)?;
    let cache: PathBuf = a
        .opt::<String>("cache")?
        .map(PathBuf::from)
        .unwrap_or_else(|| EncodedCorpus::cache_path_for(&corpus));
    a.check_unknown()?;

    let vocab = Vocab::build_from_file(&corpus, min_count)?;
    eprintln!(
        "encode: vocab {} words, corpus {} tokens",
        vocab.len(),
        vocab.total_words()
    );
    let st = EncodedCorpus::build(&corpus, &vocab, &cache)?;
    eprintln!(
        "encoded {} sentences / {} tokens ({} source bytes) in {:.1}s \
         = {} tokens/sec -> {}",
        st.sentences,
        st.tokens,
        si(st.text_bytes as f64),
        st.secs,
        si(st.tokens as f64 / st.secs.max(1e-9)),
        cache.display()
    );
    Ok(())
}
