//! Leaf subcommands: `eval` (similarity/analogy over saved vectors),
//! `simulate` (the paper's Fig 3 / Fig 4 scaling curves from the
//! calibrated performance model) and `info` (runtime diagnostics).

use crate::corpus::vocab::Vocab;
use crate::eval;
use crate::model::io as model_io;
use crate::perfmodel::{self, simulate};
use crate::util::args::Args;
use crate::util::si;

pub const EVAL_HELP: &str = "\
USAGE: pw2v eval --vectors vectors.txt [--simset sim.tsv] [--anaset ana.txt]

Evaluate saved text vectors: Spearman rho (x100) over a tab-separated
similarity set and/or top-1 accuracy over an analogy set.
";

pub const SIM_HELP: &str = "\
USAGE: pw2v simulate --figure 3|4 [--machine bdw|knl|hsw]

Regenerate the paper's scaling curves from the calibrated performance
model: Fig 3 (shared-memory thread scaling, original vs ours) or Fig 4
(cluster node scaling over the machine's fabric).
";

pub const INFO_HELP: &str = "\
USAGE: pw2v info [--artifacts-dir artifacts]

Print version, PJRT platform availability, and the compiled-artifact
manifest (HLO executables consumed by --backend pjrt).
";

pub fn eval(a: &Args) -> anyhow::Result<()> {
    let vectors: String = a.required("vectors")?;
    let simset: Option<String> = a.opt("simset")?;
    let anaset: Option<String> = a.opt("anaset")?;
    a.check_unknown()?;

    let (words, emb) = model_io::load_text(&vectors)?;
    // Rebuild a vocab view over the saved order (ranks become counts so
    // the frequency-sorted invariant holds).
    let n = words.len();
    let counts: std::collections::HashMap<String, u64> = words
        .iter()
        .enumerate()
        .map(|(i, w)| (w.clone(), (n - i) as u64))
        .collect();
    let vocab = Vocab::from_counts(counts, 1);
    eprintln!("loaded {} vectors of dim {}", n, emb.dim());

    if let Some(p) = simset {
        let pairs = eval::load_similarity_set(&p)?;
        let r = eval::eval_similarity(&pairs, &vocab, &emb);
        println!(
            "similarity: rho100 = {:.1} over {}/{} pairs",
            r.rho100, r.pairs_covered, r.pairs_total
        );
    }
    if let Some(p) = anaset {
        let qs = eval::load_analogy_set(&p)?;
        let r = eval::eval_analogy(&qs, &vocab, &emb);
        println!(
            "analogy: accuracy = {:.1}% over {}/{} questions",
            r.accuracy100(),
            r.covered,
            r.total
        );
    }
    Ok(())
}

pub fn simulate(a: &Args) -> anyhow::Result<()> {
    let figure: usize = a.get("figure", 3)?;
    let machine: String = a.get("machine", "bdw".to_string())?;
    a.check_unknown()?;
    let spec = match machine.as_str() {
        "bdw" => perfmodel::arch::broadwell(),
        "knl" => perfmodel::arch::knl(),
        "hsw" => perfmodel::arch::haswell(),
        m => anyhow::bail!("unknown machine '{m}' (bdw|knl|hsw)"),
    };
    let p = simulate::FigParams::default();
    match figure {
        3 => {
            let axis = simulate::fig3_thread_axis(&spec);
            let (scalar, gemm) =
                simulate::fig3_series(&spec, &p, 70_000.0, 182_000.0, &axis);
            println!("# Fig 3 ({}): threads original ours", spec.name);
            for (s, g) in scalar.iter().zip(&gemm) {
                println!(
                    "{:>3}  {:>10}  {:>10}",
                    s.x,
                    si(s.words_per_sec),
                    si(g.words_per_sec)
                );
            }
        }
        4 => {
            let fabric = if machine == "knl" {
                perfmodel::arch::omnipath()
            } else {
                perfmodel::arch::fdr_infiniband()
            };
            let nodes = [1, 2, 4, 8, 16, 32];
            let series =
                simulate::fig4_series(&spec, fabric, &p, 182_000.0, &nodes);
            println!("# Fig 4 ({} cluster): nodes words/sec", spec.name);
            for pt in series {
                println!("{:>3}  {:>10}", pt.x, si(pt.words_per_sec));
            }
        }
        f => anyhow::bail!("unknown figure {f} (3|4)"),
    }
    Ok(())
}

pub fn info(a: &Args) -> anyhow::Result<()> {
    let dir: String = a.get("artifacts-dir", "artifacts".to_string())?;
    a.check_unknown()?;
    println!("pw2v {}", env!("CARGO_PKG_VERSION"));
    match crate::runtime::Runtime::cpu() {
        Ok(rt) => println!("pjrt platform: {}", rt.platform()),
        Err(e) => println!("pjrt unavailable: {e}"),
    }
    match crate::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({dir}):");
            for v in &m.entries {
                println!(
                    "  {:<28} kind={:<6} W={} B={} S={} D={}",
                    v.name, v.kind, v.w, v.b, v.s, v.d
                );
            }
        }
        Err(e) => println!("artifacts: {e}"),
    }
    Ok(())
}
