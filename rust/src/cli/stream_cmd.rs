//! The `stream` subcommand: tail a growing corpus and train
//! continuously, with optional vocabulary admission, periodic
//! checkpoints and serve-ready store exports (see `stream::driver`).

use std::path::PathBuf;

use crate::config::{Backend, TrainConfig};
use crate::model::{io as model_io, Embedding};
use crate::stream::{StreamOptions, StreamTrainer};
use crate::util::args::Args;
use crate::util::si;

use super::common;

pub const HELP: &str = "\
USAGE: pw2v stream --corpus corpus.txt
         [--vocab-reserve N --checkpoint BASE --checkpoint-every FLUSHES
          --resume --follow tcp:HOST:PORT --store model.rst
          --poll-ms MS --idle-ms MS --out vectors.txt] [shared flags]

Tail `corpus.txt` as it grows and train continuously through the same
superbatch pipeline as `train`.  Over a file that never grows, a frozen
-vocab stream run is bitwise-identical to the batch trainer (pinned by
tests/stream_parity.rs).  Stream pins backend=gemm, threads=1,
epochs=1; other backends/schedules are rejected with an explanation.

  --vocab-reserve N      pre-allocate N extra model rows; unknown words
                         are counted and admitted once they clear
                         --min-count (subsample/unigram tables rebuild
                         incrementally on admission)
  --checkpoint BASE      two-slot PWCK snapshots + a .stream sidecar,
                         written every --checkpoint-every superbatch
                         flushes; --resume warm-restarts bitwise
  --follow tcp:ADDR      also accept line-oriented socket feeds and
                         append them to the corpus file
  --store model.rst      export a serve-ready row store at every
                         checkpoint (generation-stamped; `serve --watch`
                         hot-swaps to it)
  --idle-ms MS           exit after MS with no new complete line
                         (0 = run until killed); --poll-ms is the file
                         poll cadence
  --out vectors.txt      save the live rows as text vectors at exit

";

pub fn stream(a: &Args) -> anyhow::Result<()> {
    let corpus = common::corpus_arg(a)?;
    let out: Option<String> = a.opt("out")?;
    // Stream-compatible defaults; explicit flags still land on top and
    // are validated (with stream-specific messages) by the driver.
    let mut base = TrainConfig::default();
    base.backend = Backend::Gemm;
    base.threads = 1;
    base.epochs = 1;
    let cfg = common::train_config(a, base)?;
    let opts = StreamOptions {
        checkpoint: a.opt::<String>("checkpoint")?.map(PathBuf::from),
        ckpt_every: a.get("checkpoint-every", 8u64)?,
        resume: a.flag("resume"),
        poll_ms: a.get("poll-ms", 50u64)?,
        idle_ms: a.get("idle-ms", 0u64)?,
        follow: a.opt("follow")?,
        store: a.opt::<String>("store")?.map(PathBuf::from),
    };
    a.check_unknown()?;

    let mut tr = StreamTrainer::open(&cfg, &corpus, opts)?;
    eprintln!(
        "stream: vocab {} words ({} rows reserved), dim {}, resuming at \
         byte {} of {}",
        tr.vocab().len(),
        tr.model().vocab() - tr.vocab().len(),
        cfg.dim,
        tr.pos(),
        corpus.display()
    );
    let outcome = tr.run()?;
    eprintln!(
        "stream done: {} words in {:.1}s = {} words/sec, vocab {} \
         ({} admitted live), {} corpus bytes, final lr {:.5}",
        outcome.snapshot.words,
        outcome.snapshot.secs,
        si(outcome.snapshot.words_per_sec()),
        outcome.vocab_len,
        outcome.admitted,
        outcome.trained_bytes,
        outcome.final_lr
    );
    if let Some(p) = out {
        // The model over-allocates by --vocab-reserve; save only the
        // live prefix the vocabulary actually names.
        let vocab = tr.vocab();
        let m_in = tr.model().m_in();
        let mut live = Embedding::zeros(vocab.len(), m_in.dim());
        for id in 0..vocab.len() as u32 {
            live.row_mut(id).copy_from_slice(m_in.row(id));
        }
        model_io::save_text(&p, vocab, &live)?;
        eprintln!("vectors saved to {p}");
    }
    Ok(())
}
