//! The `pw2v` command-line surface.
//!
//! `main.rs` is a thin shim over [`run`]; the whole dispatchable surface
//! lives in the library so the CLI contract itself is testable —
//! `tests/cli_compat.rs` pins it end-to-end over the real binary.
//!
//! Contract:
//! - every subcommand answers `--help` with its own usage block;
//! - errors are prefixed with the subcommand name (`pw2v train: ...`);
//! - bare `pw2v <corpus>` — the original single-purpose invocation —
//!   aliases to `train --corpus <corpus>` when `<corpus>` names an
//!   existing file.
//!
//! One module per command family:
//! - [`corpus_cmd`] — `gen-corpus`, `encode`
//! - [`train_cmd`] — `train`, `train-dist`
//! - [`stream_cmd`] — `stream` (continuous ingest + training)
//! - [`serve_cmd`] — `serve` (query engine, `--watch` hot-swap)
//! - [`misc_cmd`] — `eval`, `simulate`, `info`
//! - [`common`] — the shared flag table and config plumbing

pub mod common;
pub mod corpus_cmd;
pub mod misc_cmd;
pub mod serve_cmd;
pub mod stream_cmd;
pub mod train_cmd;

use crate::util::args::Args;

const HELP: &str = "\
pw2v — Parallelizing Word2Vec in Shared and Distributed Memory (Ji et al. 2016)

USAGE: pw2v <subcommand> [--key value ...]
       pw2v <corpus>                  alias for `train --corpus <corpus>`
       pw2v <subcommand> --help       per-subcommand flags

  gen-corpus  generate a synthetic latent-model corpus + eval sets
  encode      pre-build the .pw2v.u32 encoded-corpus cache
  train       shared-memory training (backend selectable)
  train-dist  distributed data-parallel training (threads or tcp ring)
  stream      tail a growing corpus and train continuously
  eval        evaluate saved vectors on similarity/analogy sets
  serve       answer topk/analogy/stats queries over a trained model
  simulate    regenerate the paper's Fig 3 / Fig 4 scaling curves
  info        runtime + artifact diagnostics
";

type Handler = fn(&Args) -> anyhow::Result<()>;

/// Name → handler → per-command help.  Dispatch order == help order.
const COMMANDS: &[(&str, Handler, &str)] = &[
    ("gen-corpus", corpus_cmd::gen_corpus, corpus_cmd::GEN_HELP),
    ("encode", corpus_cmd::encode, corpus_cmd::ENCODE_HELP),
    ("train", train_cmd::train, train_cmd::TRAIN_HELP),
    ("train-dist", train_cmd::train_dist, train_cmd::DIST_HELP),
    ("stream", stream_cmd::stream, stream_cmd::HELP),
    ("eval", misc_cmd::eval, misc_cmd::EVAL_HELP),
    ("serve", serve_cmd::serve, serve_cmd::HELP),
    ("simulate", misc_cmd::simulate, misc_cmd::SIM_HELP),
    ("info", misc_cmd::info, misc_cmd::INFO_HELP),
];

/// What a raw argv resolves to, before anything runs.  Pure — the
/// filesystem check that legitimises [`Resolution::TrainAlias`] happens
/// in [`dispatch`], so this stays unit-testable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolution {
    /// Top-level help (empty argv included).
    Help,
    /// Index into [`COMMANDS`]; the command's arguments are `argv[1..]`.
    Command(usize),
    /// Bare `pw2v <corpus>`: run `train` over the FULL argv (the corpus
    /// rides along as a positional).
    TrainAlias,
}

pub fn resolve(argv: &[String]) -> anyhow::Result<Resolution> {
    let first = argv.first().map(String::as_str).unwrap_or("");
    if matches!(first, "" | "help" | "--help" | "-h") {
        return Ok(Resolution::Help);
    }
    if let Some(i) = COMMANDS.iter().position(|(n, ..)| *n == first) {
        return Ok(Resolution::Command(i));
    }
    anyhow::ensure!(
        !first.starts_with('-'),
        "unknown option '{first}' before a subcommand (try `pw2v help`)"
    );
    Ok(Resolution::TrainAlias)
}

/// Entry point for the binary shim: dispatch over `std::env::args`.
pub fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    dispatch(&argv)
}

pub fn dispatch(argv: &[String]) -> anyhow::Result<()> {
    let ((name, handler, help), tail) = match resolve(argv)? {
        Resolution::Help => {
            print!("{HELP}");
            return Ok(());
        }
        Resolution::Command(i) => (COMMANDS[i], &argv[1..]),
        Resolution::TrainAlias => {
            let word = argv[0].as_str();
            anyhow::ensure!(
                std::path::Path::new(word).exists(),
                "unknown subcommand '{word}' (and no such corpus file; \
                 try `pw2v help`)"
            );
            let train = COMMANDS
                .iter()
                .find(|(n, ..)| *n == "train")
                .copied()
                .expect("train is always registered");
            (train, argv)
        }
    };
    let args = Args::parse(tail.iter().cloned());
    // `--help` anywhere in the tail prints the command's usage.  The
    // parser binds `--help <value>` as an option, so check both shapes.
    if args.flag("help") || args.opt::<String>("help")?.is_some() {
        print!("{help}");
        if help.contains("[shared flags]") {
            print!("{}", common::SHARED_FLAGS);
        }
        return Ok(());
    }
    handler(&args).map_err(|e| e.context(format!("pw2v {name}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn known_subcommands_resolve_by_name() {
        for (i, (name, ..)) in COMMANDS.iter().enumerate() {
            let r = resolve(&argv(&format!("{name} --x 1"))).unwrap();
            assert_eq!(r, Resolution::Command(i), "{name}");
        }
    }

    #[test]
    fn empty_and_help_spellings_resolve_to_help() {
        for s in ["", "help", "--help", "-h"] {
            assert_eq!(resolve(&argv(s)).unwrap(), Resolution::Help, "{s:?}");
        }
    }

    #[test]
    fn bare_word_resolves_to_the_train_alias() {
        assert_eq!(
            resolve(&argv("corpus.txt --dim 8")).unwrap(),
            Resolution::TrainAlias
        );
    }

    #[test]
    fn leading_option_is_rejected() {
        let e = resolve(&argv("--dim 8")).unwrap_err().to_string();
        assert!(e.contains("--dim") || e.contains("-dim"), "{e}");
    }

    #[test]
    fn alias_for_a_missing_file_names_the_word() {
        let e = dispatch(&argv("frobnicate")).unwrap_err().to_string();
        assert!(e.contains("unknown subcommand 'frobnicate'"), "{e}");
    }

    #[test]
    fn errors_are_prefixed_with_the_subcommand() {
        // eval without --vectors must fail, and the context names it.
        let e = format!("{:#}", dispatch(&argv("eval")).unwrap_err());
        assert!(e.starts_with("pw2v eval"), "{e}");
        assert!(e.contains("--vectors"), "{e}");
    }
}
