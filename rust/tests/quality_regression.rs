//! Quality-regression guard: seeded end-to-end training on the
//! synthetic corpus must keep embedding QUALITY — Spearman ρ against the
//! planted latent similarities and 3CosAdd accuracy on the planted
//! analogies — above fixed floors for every backend × kernel × route
//! combination.
//!
//! The parity suites (`backend_parity`, `numa_parity`, `routing_parity`)
//! pin that optimisations don't change WHAT is computed; this is the
//! first tier-1 guard that the growing feature matrix (kernel × simd ×
//! corpus-cache × numa × routing) also keeps LEARNING — a knob
//! combination that silently dropped windows, mis-scattered gradients,
//! or broke the lr schedule would still pass bitwise-off parity legs but
//! lands here.
//!
//! Floors are deliberately conservative (chance ρ ≈ 0, chance analogy
//! accuracy ≈ 1/vocab = 0.05%): they catch "stopped learning", not
//! run-to-run Hogwild noise.  The CI matrix reruns this file under
//! pinned-scalar dispatch, a synthetic two-node topology, and the
//! buffered (non-mmap) cache reader.

use pw2v::config::{Backend, CorpusCacheMode, KernelMode};
use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::eval;
use pw2v::SharedModel;
use pw2v::runtime::topology::NumaMode;
use pw2v::train;
use pw2v::train::route::RouteMode;

/// Spearman ρ×100 floor per combination (typical healthy runs on this
/// fixture score far higher; chance is ~0).
const RHO_FLOOR: f64 = 15.0;
/// Analogy accuracy (%) floor — ≥10× chance (1/vocab = 0.05%); asserted
/// on the GEMM combinations (the paper's scheme).
const ANALOGY_FLOOR: f64 = 0.5;

struct Fixture {
    corpus: std::path::PathBuf,
    vocab: Vocab,
    latent: LatentModel,
}

impl Drop for Fixture {
    fn drop(&mut self) {
        std::fs::remove_file(&self.corpus).ok();
    }
}

fn fixture() -> Fixture {
    let scfg = SyntheticConfig {
        vocab: 2_000,
        tokens: 300_000,
        clusters: 20,
        beta: 5.0,
        seed: 29,
        ..SyntheticConfig::default()
    };
    let latent = LatentModel::new(scfg);
    let corpus = std::env::temp_dir().join(format!(
        "pw2v_quality_{}.txt",
        std::process::id()
    ));
    latent.write_corpus(&corpus).unwrap();
    let vocab = Vocab::build_from_file(&corpus, 1).unwrap();
    Fixture {
        corpus,
        vocab,
        latent,
    }
}

/// One test drives the whole matrix so the fixture is generated once and
/// the heavy trainings never oversubscribe each other.
#[test]
fn quality_floors_across_backend_kernel_route_matrix() {
    let f = fixture();
    let sim_set = eval::gen_similarity_set(&f.latent, 200, 3);
    let ana_set = eval::gen_analogy_set(&f.latent);
    assert!(ana_set.len() > 50, "planted analogy set too small");

    // (backend, kernel, route, numa, corpus-cache) — every backend with
    // routing off AND on; both GEMM kernel organisations; the routed
    // legs on the two-node sharded store; one leg from the encoded
    // cache so the full feature stack (kernel × cache × numa × route)
    // trains together at least once.
    let combos: &[(Backend, KernelMode, RouteMode, NumaMode, CorpusCacheMode)] = &[
        (
            Backend::Scalar,
            KernelMode::Auto,
            RouteMode::Off,
            NumaMode::Off,
            CorpusCacheMode::Off,
        ),
        (
            Backend::Scalar,
            KernelMode::Auto,
            RouteMode::Owner,
            NumaMode::Nodes(2),
            CorpusCacheMode::Off,
        ),
        (
            Backend::Bidmach,
            KernelMode::Auto,
            RouteMode::Off,
            NumaMode::Off,
            CorpusCacheMode::Off,
        ),
        (
            Backend::Bidmach,
            KernelMode::Auto,
            RouteMode::Owner,
            NumaMode::Nodes(2),
            CorpusCacheMode::Off,
        ),
        (
            Backend::Gemm,
            KernelMode::Fused,
            RouteMode::Off,
            NumaMode::Off,
            CorpusCacheMode::Off,
        ),
        (
            Backend::Gemm,
            KernelMode::Fused,
            RouteMode::Owner,
            NumaMode::Nodes(2),
            CorpusCacheMode::Auto,
        ),
        (
            Backend::Gemm,
            KernelMode::Gemm3,
            RouteMode::Off,
            NumaMode::Off,
            CorpusCacheMode::Off,
        ),
        (
            Backend::Gemm,
            KernelMode::Gemm3,
            RouteMode::Head(96),
            NumaMode::Nodes(2),
            CorpusCacheMode::Off,
        ),
    ];

    for (backend, kernel, route, numa, cache) in combos.iter().cloned() {
        let tag = format!("{backend}/{kernel}/{route}/{numa}/{cache}");
        let mut cfg = TrainConfig::default();
        cfg.backend = backend;
        cfg.kernel = kernel;
        cfg.route = route;
        cfg.numa = numa;
        cfg.corpus_cache = cache;
        cfg.dim = 48;
        cfg.epochs = 2;
        cfg.threads = 2;
        cfg.sample = 1e-3;
        cfg.lr = 0.05;
        let model = SharedModel::init(f.vocab.len(), cfg.dim, cfg.seed);
        let out = train::train(&cfg, &f.corpus, &f.vocab, &model).unwrap();
        assert_eq!(
            out.snapshot.words,
            cfg.epochs as u64 * f.vocab.total_words(),
            "{tag}: word accounting"
        );
        let sim = eval::eval_similarity(&sim_set, &f.vocab, model.m_in());
        assert!(
            sim.pairs_covered > 150,
            "{tag}: similarity coverage {}/{}",
            sim.pairs_covered,
            sim.pairs_total
        );
        assert!(
            sim.rho100 > RHO_FLOOR,
            "{tag}: rho100 {:.1} below quality floor {RHO_FLOOR}",
            sim.rho100
        );
        if backend == Backend::Gemm {
            let ana = eval::eval_analogy(&ana_set, &f.vocab, model.m_in());
            assert!(ana.covered > 0, "{tag}: no analogy coverage");
            assert!(
                ana.accuracy100() > ANALOGY_FLOOR,
                "{tag}: analogy accuracy {:.2}% below floor {ANALOGY_FLOOR}%",
                ana.accuracy100()
            );
        }
    }

    let cache =
        pw2v::EncodedCorpus::cache_path_for(&f.corpus);
    std::fs::remove_file(&cache).ok();
}
