//! Serve-engine parity acceptance: the `serve` scan must answer exactly
//! what the eval layer's brute-force oracles compute.
//!
//! Legs (one test fn: the trained fixture is built once, and the
//! dispatch level is process-global):
//!
//! 1. **topk vs oracle** — trained fixture; the oracle is the
//!    brute-force unit-row dot scan with `linalg::dot` (the exact
//!    arithmetic of `eval::analogy`'s argmax).  Under SCALAR dispatch
//!    the serve scan is bit-for-bit this oracle: ids AND score bits
//!    must match.  Under AVX2 FMA reassociates the reduction, so a
//!    rank swap is tolerated only where the oracle itself scores the
//!    two ids within a near-tie margin.
//! 2. **analogy vs `eval_analogy`** — serve top-1 per covered question
//!    against the replicated per-question oracle, and (scalar) the
//!    aggregate `correct` count against `eval_analogy`'s own report.
//! 3. **int8 recall@10 ≥ 0.95** against the f32 scan — the acceptance
//!    gate for `--quant int8` (accounting in EXPERIMENTS.md §Serving).
//! 4. **planted large-margin fixture** — strict id equality under BOTH
//!    dispatch levels (margins far beyond any reassociation noise).
//!
//! `PW2V_SIMD=scalar` (the CI dispatch-matrix leg) pins the whole file
//! to the portable kernels, upgrading every tolerance to exactness.

use pw2v::config::QuantMode;
use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::eval;
use pw2v::eval::analogy::normalized_matrix;
use pw2v::linalg::simd::{self, SimdLevel, SimdMode};
use pw2v::model::{Embedding, SharedModel};
use pw2v::serve::Scratch;
use pw2v::{RowStore, ServeEngine};
use pw2v::train;

/// Near-tie margin for AVX2 rank swaps: two candidates whose ORACLE
/// scores differ by more than this must never swap.
const NEAR_TIE: f32 = 1e-5;
/// Int8 acceptance floor.
const INT8_RECALL_FLOOR: f64 = 0.95;

fn env_mode() -> SimdMode {
    match std::env::var("PW2V_SIMD").as_deref() {
        Ok("scalar") => SimdMode::Scalar,
        Ok("avx2") => SimdMode::Avx2,
        _ => SimdMode::Auto,
    }
}

/// Brute-force oracle: rank every servable row (except the exclusions)
/// by `linalg::dot` against `query`, score desc, tie → lower id.
fn oracle_rank(
    unit: &[f32],
    d: usize,
    servable: &[bool],
    exclude: &[u32],
    query: &[f32],
    k: usize,
) -> Vec<(u32, f32)> {
    let n = unit.len() / d;
    let mut scored: Vec<(u32, f32)> = (0..n as u32)
        .filter(|w| !exclude.contains(w) && servable[*w as usize])
        .map(|w| {
            let row = &unit[w as usize * d..(w as usize + 1) * d];
            (w, pw2v::linalg::dot(row, query))
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored.truncate(k);
    scored
}

/// Compare a serve hit list against the oracle's: exact when `strict`,
/// else rank swaps only within the oracle's near-tie margin.
fn assert_hits_match(
    tag: &str,
    serve: &[(u32, f32)],
    oracle: &[(u32, f32)],
    full_oracle: &[(u32, f32)],
    strict: bool,
) {
    assert_eq!(serve.len(), oracle.len(), "{tag}: hit count");
    if strict {
        for (i, (s, o)) in serve.iter().zip(oracle).enumerate() {
            assert_eq!(s.0, o.0, "{tag}: rank {i} id");
            assert_eq!(
                s.1.to_bits(),
                o.1.to_bits(),
                "{tag}: rank {i} score bits ({} vs {})",
                s.1,
                o.1
            );
        }
        return;
    }
    // AVX2: scores agree loosely everywhere, and any positional
    // mismatch must be a near-tie in the ORACLE's own scores.
    let score_of = |id: u32| -> f32 {
        full_oracle
            .iter()
            .find(|(w, _)| *w == id)
            .unwrap_or_else(|| panic!("{tag}: id {id} not in oracle ranking"))
            .1
    };
    for (i, (s, o)) in serve.iter().zip(oracle).enumerate() {
        assert!(
            (s.1 - score_of(s.0)).abs() <= 1e-4,
            "{tag}: rank {i} serve score {} far from oracle {}",
            s.1,
            score_of(s.0)
        );
        if s.0 != o.0 {
            let gap = (score_of(s.0) - o.1).abs();
            assert!(
                gap <= NEAR_TIE,
                "{tag}: rank {i} swapped {} for {} with oracle gap {gap:.2e}",
                s.0,
                o.0
            );
        }
    }
}

#[test]
fn serve_answers_match_eval_oracles() {
    let level = simd::configure(env_mode()).unwrap();
    let strict = level == SimdLevel::Scalar;

    // ---- trained fixture (single-threaded: bitwise deterministic) ----
    let scfg = SyntheticConfig {
        vocab: 800,
        tokens: 120_000,
        clusters: 16,
        beta: 5.0,
        seed: 31,
        ..SyntheticConfig::default()
    };
    let latent = LatentModel::new(scfg);
    let corpus = std::env::temp_dir().join(format!("pw2v_serve_parity_{}.txt", std::process::id()));
    latent.write_corpus(&corpus).unwrap();
    let vocab = Vocab::build_from_file(&corpus, 1).unwrap();
    let mut cfg = TrainConfig::default();
    cfg.dim = 32;
    cfg.epochs = 2;
    cfg.threads = 1;
    cfg.sample = 1e-3;
    cfg.lr = 0.05;
    // train() re-pins dispatch from cfg.simd; keep it at the mode this
    // test run is exercising so the serve legs stay on that level.
    cfg.simd = env_mode();
    let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
    train::train(&cfg, &corpus, &vocab, &model).unwrap();
    std::fs::remove_file(&corpus).ok();
    let emb = model.m_in();

    let words: Vec<String> = (0..vocab.len() as u32)
        .map(|id| vocab.word(id).to_string())
        .collect();
    let unit = normalized_matrix(emb);
    let d = cfg.dim;
    let servable: Vec<bool> = (0..vocab.len() as u32)
        .map(|id| pw2v::eval::similarity::row_servable(emb.row(id)))
        .collect();

    let eng = ServeEngine::from_store(
        RowStore::from_model(words.clone(), emb).unwrap(),
        QuantMode::Off,
    )
    .unwrap();
    let mut s = Scratch::default();
    let queries: Vec<u32> = (0..25u32)
        .map(|i| (i * 31) % vocab.len() as u32)
        .filter(|&q| servable[q as usize])
        .collect();
    assert!(queries.len() >= 20, "fixture produced degenerate rows");

    // ---- leg 1: topk vs brute-force oracle --------------------------
    for &q in &queries {
        let serve: Vec<(u32, f32)> = eng
            .topk(q, 10, &mut s)
            .iter()
            .map(|h| (h.id, h.score))
            .collect();
        let qrow = &unit[q as usize * d..(q as usize + 1) * d];
        let full = oracle_rank(&unit, d, &servable, &[q], qrow, vocab.len());
        assert_hits_match(
            &format!("topk({})", vocab.word(q)),
            &serve,
            &full[..10],
            &full,
            strict,
        );
    }

    // ---- leg 2: analogy vs eval_analogy -----------------------------
    let qs = eval::gen_analogy_set(&latent);
    let mut covered = 0usize;
    let mut serve_correct = 0usize;
    for q in &qs {
        let (Some(ia), Some(ib), Some(ic), Some(id_)) =
            (vocab.id(&q.a), vocab.id(&q.b), vocab.id(&q.c), vocab.id(&q.d))
        else {
            continue;
        };
        covered += 1;
        let mut query = vec![0.0f32; d];
        let (ua, ub, uc) = (
            &unit[ia as usize * d..(ia as usize + 1) * d],
            &unit[ib as usize * d..(ib as usize + 1) * d],
            &unit[ic as usize * d..(ic as usize + 1) * d],
        );
        for l in 0..d {
            query[l] = ub[l] - ua[l] + uc[l];
        }
        let full = oracle_rank(&unit, d, &servable, &[ia, ib, ic], &query, vocab.len());
        let serve: Vec<(u32, f32)> = eng
            .analogy(ia, ib, ic, 1, &mut s)
            .iter()
            .map(|h| (h.id, h.score))
            .collect();
        assert_hits_match(
            &format!("analogy({}:{}::{})", q.a, q.b, q.c),
            &serve,
            &full[..1],
            &full,
            strict,
        );
        if serve[0].0 == id_ {
            serve_correct += 1;
        }
    }
    assert!(covered > 50, "analogy coverage too small: {covered}");
    if strict {
        // The aggregate anchor: serve's per-question top-1 reproduces
        // eval_analogy's correct count exactly (same arithmetic, same
        // tie policy, same exclusions).
        let report = eval::eval_analogy(&qs, &vocab, emb);
        assert_eq!(report.covered, covered, "coverage accounting");
        assert_eq!(
            serve_correct, report.correct,
            "serve analogy disagrees with eval_analogy's correct count"
        );
    }

    // ---- leg 3: int8 recall@10 --------------------------------------
    let eng8 = ServeEngine::from_store(
        RowStore::from_model(words.clone(), emb).unwrap(),
        QuantMode::Int8,
    )
    .unwrap();
    assert!(eng8.quantized());
    let mut overlap = 0usize;
    let mut total = 0usize;
    for &q in &queries {
        let f: Vec<u32> = eng.topk(q, 10, &mut s).iter().map(|h| h.id).collect();
        let i8s: Vec<u32> = eng8.topk(q, 10, &mut s).iter().map(|h| h.id).collect();
        overlap += i8s.iter().filter(|id| f.contains(id)).count();
        total += f.len();
    }
    let recall = overlap as f64 / total as f64;
    assert!(
        recall >= INT8_RECALL_FLOOR,
        "int8 recall@10 = {recall:.3} below the {INT8_RECALL_FLOOR} gate \
         ({overlap}/{total} over {} queries)",
        queries.len()
    );

    // ---- leg 4: planted large-margin fixture, both dispatch levels --
    let pwords: Vec<String> = ["anchor", "near", "mid", "far", "anti"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let mut pemb = Embedding::zeros(5, 4);
    pemb.row_mut(0).copy_from_slice(&[1.0, 0.0, 0.0, 0.0]);
    pemb.row_mut(1).copy_from_slice(&[0.9, 0.1, 0.0, 0.0]);
    pemb.row_mut(2).copy_from_slice(&[0.5, 0.5, 0.5, 0.0]);
    pemb.row_mut(3).copy_from_slice(&[0.0, 0.0, 1.0, 0.0]);
    pemb.row_mut(4).copy_from_slice(&[-1.0, 0.0, 0.0, 0.0]);
    let modes: &[SimdMode] = if matches!(env_mode(), SimdMode::Scalar) {
        &[SimdMode::Scalar]
    } else {
        &[SimdMode::Scalar, SimdMode::Auto]
    };
    for &mode in modes {
        simd::configure(mode).unwrap();
        for quant in [QuantMode::Off, QuantMode::Int8] {
            let peng = ServeEngine::from_store(
                RowStore::from_model(pwords.clone(), &pemb).unwrap(),
                quant,
            )
            .unwrap();
            let ids: Vec<u32> = peng.topk(0, 4, &mut s).iter().map(|h| h.id).collect();
            assert_eq!(
                ids,
                vec![1, 2, 3, 4],
                "planted topk order must be dispatch- and quant-invariant \
                 ({mode:?}/{quant:?})"
            );
        }
    }
    simd::configure(env_mode()).unwrap();
}
