//! Routing-path parity: `--route off` (every worker processes its own
//! windows — the pre-routing path bit-for-bit) versus the
//! ownership-routed exchange (`--route {owner,head=<K>}`).
//!
//! Routing classifies windows at generation time and moves them between
//! workers through bounded SPSC mailboxes; it never changes WHICH
//! windows exist (the RNG streams are sink-independent) — only where
//! each one is processed.  Hence:
//!
//! * at 1 worker thread every window classifies back to its own arena
//!   and the routed knob must be BITWISE identical to `--route off`, for
//!   both kernel organisations, with and without the NUMA-sharded store,
//!   and from both corpus ingest backends;
//! * at several threads Hogwild races make every run nondeterministic;
//!   the suite bounds the routed-vs-unrouted drift with the shared
//!   gap-vs-movement machinery (`tests/common`);
//! * the debug remote-row counters must show `--route owner` STRICTLY
//!   below `--numa` alone on a synthetic two-node geometry — the PR's
//!   acceptance criterion (`--numa 2` here builds the same two-node
//!   shard map the CI matrix's `PW2V_TOPOLOGY="0;0"` rerun detects).
//!
//! The trainings in this file are serialised behind one lock: the
//! remote-row counters are process-wide, so concurrent numa-mode
//! trainings from sibling tests would pollute the deltas.

use pw2v::config::{CorpusCacheMode, KernelMode};
use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::model::{reset_row_access_stats, row_access_stats, SharedModel};
use pw2v::runtime::topology::NumaMode;
use pw2v::train;
use pw2v::train::route::RouteMode;

mod common;

/// Serialises every training in this binary (see module docs).
/// `unwrap_or_else(into_inner)` keeps a poisoned lock usable — a failed
/// sibling test should report ITS assertion, not poison ours.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_corpus(seed: u64) -> (std::path::PathBuf, Vocab) {
    let mut scfg = SyntheticConfig::test_tiny();
    scfg.tokens = 30_000;
    scfg.seed = seed;
    let lm = LatentModel::new(scfg);
    let path = std::env::temp_dir().join(format!(
        "pw2v_route_parity_{seed}_{}.txt",
        std::process::id()
    ));
    lm.write_corpus(&path).unwrap();
    let vocab = Vocab::build_from_file(&path, 1).unwrap();
    (path, vocab)
}

fn train_with(
    cfg: &TrainConfig,
    path: &std::path::Path,
    vocab: &Vocab,
) -> (SharedModel, u64, u64) {
    let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
    let out = train::train(cfg, path, vocab, &model).unwrap();
    (model, out.snapshot.words, out.snapshot.windows)
}

/// One worker thread: routed ≡ unrouted BITWISE for both kernels, both
/// route modes, with the flat and the NUMA-sharded store.
#[test]
fn single_thread_bitwise_across_route_modes() {
    let _g = lock();
    let (path, vocab) = tiny_corpus(91);
    for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
        for numa in [NumaMode::Off, NumaMode::Nodes(2)] {
            let mut cfg = TrainConfig::test_tiny();
            cfg.kernel = kernel;
            cfg.sample = 0.0;
            cfg.numa = numa;
            cfg.route = RouteMode::Off;
            let (base, base_words, base_windows) =
                train_with(&cfg, &path, &vocab);
            assert_eq!(base_words, vocab.total_words());
            for route in [RouteMode::Owner, RouteMode::Head(8)] {
                cfg.route = route;
                let (routed, words, windows) = train_with(&cfg, &path, &vocab);
                assert_eq!(words, base_words, "{kernel}/{numa}/{route}");
                assert_eq!(windows, base_windows, "{kernel}/{numa}/{route}");
                assert_eq!(
                    base.m_in().data(),
                    routed.m_in().data(),
                    "{kernel}/{numa}/{route}: M_in diverged from --route off"
                );
                assert_eq!(
                    base.m_out().data(),
                    routed.m_out().data(),
                    "{kernel}/{numa}/{route}: M_out diverged from --route off"
                );
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Cross-feature leg: 1-thread routed training from the encoded corpus
/// cache is bitwise the routed text-streaming run (routing and the
/// ingest seam compose without perturbing either guarantee).
#[test]
fn routed_encoded_cache_matches_text_bitwise() {
    let _g = lock();
    let (path, vocab) = tiny_corpus(97);
    let cache = pw2v::EncodedCorpus::cache_path_for(&path);
    std::fs::remove_file(&cache).ok();
    let mut cfg = TrainConfig::test_tiny();
    cfg.sample = 0.0;
    cfg.route = RouteMode::Owner;
    cfg.numa = NumaMode::Nodes(2);
    let (text, text_words, _) = train_with(&cfg, &path, &vocab);
    cfg.corpus_cache = CorpusCacheMode::Auto;
    let (cached, cached_words, _) = train_with(&cfg, &path, &vocab);
    assert_eq!(text_words, cached_words);
    assert!(cache.exists());
    assert_eq!(text.m_in().data(), cached.m_in().data());
    assert_eq!(text.m_out().data(), cached.m_out().data());
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cache).ok();
}

/// Multi-threaded: routing changes which worker processes a window, so
/// Hogwild interleavings differ — the drift must stay in the race-noise
/// envelope (well below signal), with full word AND window conservation.
#[test]
fn multithreaded_routed_drift_is_bounded() {
    let _g = lock();
    let (path, vocab) = tiny_corpus(93);
    let mut cfg = TrainConfig::test_tiny();
    cfg.threads = 4;
    cfg.sample = 0.0;
    cfg.numa = NumaMode::Nodes(2);
    cfg.route = RouteMode::Off;
    let (base, words_off, windows_off) = train_with(&cfg, &path, &vocab);
    assert_eq!(words_off, vocab.total_words());
    for route in [RouteMode::Owner, RouteMode::Head(64)] {
        cfg.route = route;
        let (routed, words, windows) = train_with(&cfg, &path, &vocab);
        assert_eq!(words, words_off, "{route}: word accounting");
        assert_eq!(windows, windows_off, "{route}: window conservation");
        let (gap, moved) =
            common::model_gap(&base, &routed, vocab.len(), cfg.dim, cfg.seed);
        assert!(moved > 1e-4, "{route}: model did not move ({moved})");
        assert!(
            gap < moved,
            "{route}: routed vs unrouted drift {gap} not below movement \
             {moved}"
        );
    }
    std::fs::remove_file(&path).ok();
}

/// THE acceptance counter: on a two-node shard geometry, `--route owner`
/// must strictly reduce the remote share of sharded row accesses below
/// `--numa` alone.  Ownership steers every routed-head window to the
/// worker whose node holds the target row, so target gathers/scatters
/// that were ~50% remote become mostly local; inputs and negatives are
/// untouched, hence "strictly below", not "near zero".
#[test]
fn routed_head_cuts_remote_share() {
    if !cfg!(debug_assertions) {
        eprintln!("skipping: remote-row counters are debug-only");
        return;
    }
    let _g = lock();
    let (path, vocab) = tiny_corpus(95);
    let mut cfg = TrainConfig::test_tiny();
    cfg.threads = 2;
    cfg.sample = 0.0;
    cfg.numa = NumaMode::Nodes(2);

    let mut share = |route: RouteMode| {
        cfg.route = route;
        reset_row_access_stats();
        let (_, words, _) = train_with(&cfg, &path, &vocab);
        assert_eq!(words, vocab.total_words(), "{route}");
        let (total, remote) = row_access_stats();
        assert!(total > 0, "{route}: no sharded accesses counted");
        assert!(remote <= total, "{route}");
        remote as f64 / total as f64
    };
    let share_numa_alone = share(RouteMode::Off);
    let share_routed = share(RouteMode::Owner);
    assert!(
        share_routed < share_numa_alone,
        "--route owner must strictly reduce remote share: \
         {share_routed:.4} vs {share_numa_alone:.4} under --numa alone"
    );
    std::fs::remove_file(&path).ok();
}
