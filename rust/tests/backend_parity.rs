//! Backend-path parity: the legacy window-at-a-time `Backend::process`
//! and the superbatch `Backend::process_arena` must train equivalent
//! models on a single thread with a fixed seed — for EVERY backend, so a
//! fused-path (or any arena-path) drift is caught at the trainer surface,
//! not just at kernel level.
//!
//! Two regimes:
//!
//! * **disjoint windows** (no id shared between windows): the arena's
//!   dedup + deferred `dWo` scatter collapse to the window path's exact
//!   computation — parity is essentially bitwise;
//! * **overlapping windows** (shared negatives, repeated contexts — the
//!   realistic stream): the arena path reads pre-superbatch `Wo` state by
//!   design (paper Sec. III-C, update-count reduction), so parity is
//!   near-equality over ONE superbatch at small lr, not bit-equality.

use pw2v::config::KernelMode;
use pw2v::Vocab;
use pw2v::SharedModel;
use pw2v::sampling::batch::{BatchBuilder, SuperbatchArena, Window};
use pw2v::sampling::unigram::UnigramSampler;
use pw2v::train::sgd_bidmach::BidmachBackend;
use pw2v::train::sgd_gemm::GemmBackend;
use pw2v::train::sgd_scalar::ScalarBackend;
use pw2v::train::Backend;
use pw2v::util::rng::Xoshiro256ss;
use std::collections::HashMap;

mod common;

const DIM: usize = 16;
const VOCAB: usize = 120;
const SEED: u64 = 4242;

fn vocab() -> Vocab {
    let counts: HashMap<String, u64> = (0..VOCAB)
        .map(|i| (format!("w{i:03}"), (10_000 / (i + 1)) as u64))
        .collect();
    Vocab::from_counts(counts, 1)
}

fn arena_of(windows: &[Window]) -> SuperbatchArena {
    let mut a = SuperbatchArena::new(16, 6);
    for w in windows {
        a.push_window(&w.inputs, &w.outputs);
    }
    a
}

/// Shared drift-vs-movement machinery (`tests/common/mod.rs`) bound to
/// this suite's fixed geometry.
fn model_gap(a: &SharedModel, b: &SharedModel) -> (f64, f64) {
    common::model_gap(a, b, VOCAB, DIM, SEED)
}

/// Runs `process` vs `process_arena` through two same-seeded backend
/// instances and returns (gap, moved).
fn run_both<B: Backend>(
    mut make: impl FnMut() -> B,
    windows: &[Window],
    lr: f32,
) -> (f64, f64) {
    let model_w = SharedModel::init(VOCAB, DIM, SEED);
    let model_a = SharedModel::init(VOCAB, DIM, SEED);
    let mut bw = make();
    bw.process(model_w.store(), windows, lr).unwrap();
    let arena = arena_of(windows);
    let mut ba = make();
    ba.process_arena(model_a.store(), &arena, lr).unwrap();
    model_gap(&model_w, &model_a)
}

/// Windows with pairwise-disjoint id sets: 8 windows, ids carved from
/// consecutive ranges (3 inputs + 1 target + 5 negatives = 9 ids each).
fn disjoint_windows() -> Vec<Window> {
    (0..8u32)
        .map(|w| {
            let base = w * 9;
            Window {
                inputs: vec![base, base + 1, base + 2],
                outputs: (base + 3..base + 9).collect(),
            }
        })
        .collect()
}

/// A realistic overlapping superbatch: windows built by the actual
/// `BatchBuilder` over a repetitive sentence (shared negatives from the
/// Zipf sampler, contexts repeating across windows).
fn overlapping_windows(sampler: &UnigramSampler) -> Vec<Window> {
    let b = BatchBuilder::new(sampler, 4, 16, 5);
    let sent: Vec<u32> = (0..48u32).map(|i| (i * 7) % 40).collect();
    let mut rng = Xoshiro256ss::new(SEED);
    b.windows_of(&sent, &mut rng)
}

#[test]
fn disjoint_windows_agree_for_every_backend() {
    let vc = vocab();
    let sampler = UnigramSampler::alias(&vc, 0.75);
    let windows = disjoint_windows();
    let lr = 0.025f32;

    let mut check = |name: &str, tol: f64, out: (f64, f64)| {
        let (gap, moved) = out;
        assert!(moved > 1e-4, "{name}: model did not move ({moved})");
        assert!(
            gap <= tol,
            "{name}: window vs arena path diverged by {gap} (tol {tol})"
        );
    };
    // Scalar/Bidmach use the default (materialising) process_arena:
    // identical code path, so parity is exact.
    check(
        "scalar",
        0.0,
        run_both(|| ScalarBackend::new(&sampler, 5, DIM, SEED), &windows, lr),
    );
    check(
        "bidmach",
        0.0,
        run_both(|| BidmachBackend::new(16), &windows, lr),
    );
    // Gemm: disjoint ids collapse dedup/deferral to the window-path
    // computation — near-bitwise for both kernel organisations.
    check(
        "gemm/fused",
        1e-6,
        run_both(
            || GemmBackend::new(DIM, 16, 6).with_kernel(KernelMode::Fused),
            &windows,
            lr,
        ),
    );
    check(
        "gemm/gemm3",
        1e-6,
        run_both(
            || GemmBackend::new(DIM, 16, 6).with_kernel(KernelMode::Gemm3),
            &windows,
            lr,
        ),
    );
}

#[test]
fn overlapping_superbatch_stays_equivalent() {
    let vc = vocab();
    let sampler = UnigramSampler::alias(&vc, 0.75);
    let windows = overlapping_windows(&sampler);
    assert!(windows.len() >= 40, "workload too small: {}", windows.len());
    let lr = 0.01f32;

    // Scalar/Bidmach take the default (materialising) arena path: exact.
    let (gap, moved) = run_both(
        || ScalarBackend::new(&sampler, 5, DIM, SEED),
        &windows,
        lr,
    );
    assert!(moved > 1e-4 && gap == 0.0, "scalar: gap {gap}, moved {moved}");
    let (gap, moved) = run_both(|| BidmachBackend::new(16), &windows, lr);
    assert!(moved > 1e-4 && gap == 0.0, "bidmach: gap {gap}, moved {moved}");

    // Gemm defers dWo to superbatch end (reads pre-superbatch Wo state):
    // near-equality over one superbatch at small lr, for BOTH kernels.
    for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
        let (gap, moved) = run_both(
            || GemmBackend::new(DIM, 16, 6).with_kernel(kernel),
            &windows,
            lr,
        );
        assert!(moved > 1e-4, "gemm/{kernel}: model did not move");
        assert!(
            gap < 5e-3,
            "gemm/{kernel}: window vs arena drifted by {gap}"
        );
        assert!(
            gap < moved,
            "gemm/{kernel}: drift {gap} not small vs movement {moved}"
        );
    }
}

// ---------------------------------------------------------------------------
// PR 10: `--reuse` trainer-surface parity.  The reuse driver regroups a
// sentence's windows into runs, so its contract lives HERE, at the
// backend surface, on arenas the real `BatchBuilder` filled.
// ---------------------------------------------------------------------------

use pw2v::config::ReuseMode;
use pw2v::linalg::simd::{self, SimdMode};

/// CI dispatch-leg pinning for the reuse tests below (`PW2V_SIMD=scalar`
/// or `PW2V_SIMD=avx512`; those legs run with `--test-threads=1`, so
/// pinning the process-global dispatch level cannot race the other
/// tests in this binary).  Returns false when the pinned tier is not
/// available on this CPU — the caller soft-skips, log line already
/// emitted.  Without the env var the tests run at the ambient
/// auto-detected level and never touch the dispatch pin.
fn pin_simd_leg() -> bool {
    match std::env::var("PW2V_SIMD").as_deref() {
        Ok("avx512") => {
            if simd::configure(SimdMode::Avx512).is_err() {
                eprintln!(
                    "PW2V_SIMD=avx512: this CPU lacks avx512f+avx512bw, \
                     backend_parity reuse legs soft-skipped"
                );
                return false;
            }
            true
        }
        Ok("scalar") => {
            simd::configure(SimdMode::Scalar).unwrap();
            true
        }
        _ => true,
    }
}

fn unpin_simd_leg() {
    if std::env::var("PW2V_SIMD").is_ok() {
        simd::configure(SimdMode::Auto).unwrap();
    }
}

/// Sentences of awkward lengths (a 48-word run, a singleton that emits
/// no windows, short tails) filled through the real builder under the
/// given reuse mode.
fn reuse_arena(sampler: &UnigramSampler, reuse: ReuseMode) -> SuperbatchArena {
    let mut b = BatchBuilder::new(sampler, 4, 16, 5).with_reuse(reuse);
    let mut arena = SuperbatchArena::new(16, 6);
    let mut rng = Xoshiro256ss::new(SEED);
    for len in [48usize, 1, 7, 23] {
        let sent: Vec<u32> =
            (0..len as u32).map(|i| (i * 7 + len as u32) % 40).collect();
        b.fill_arena(&sent, &mut rng, &mut arena);
    }
    arena
}

fn run_reuse(
    kernel: KernelMode,
    reuse: ReuseMode,
    arena: &SuperbatchArena,
    lr: f32,
) -> SharedModel {
    let model = SharedModel::init(VOCAB, DIM, SEED);
    let mut b = GemmBackend::new(DIM, 16, 6)
        .with_kernel(kernel)
        .with_reuse(reuse);
    b.process_arena(model.store(), arena, lr).unwrap();
    model
}

/// `--reuse window` is the driver-overhead ablation: same sampled
/// stream, runs pinned to length one — BIT-FOR-BIT `--reuse off`, for
/// both kernel organisations.
#[test]
fn window_reuse_is_bitwise_off() {
    if !pin_simd_leg() {
        return;
    }
    let vc = vocab();
    let sampler = UnigramSampler::alias(&vc, 0.75);
    let arena = reuse_arena(&sampler, ReuseMode::Off);
    let arena_w = reuse_arena(&sampler, ReuseMode::Window);
    assert_eq!(
        arena.to_windows(),
        arena_w.to_windows(),
        "window reuse must not perturb the sampled stream"
    );
    for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
        let off = run_reuse(kernel, ReuseMode::Off, &arena, 0.025);
        let win = run_reuse(kernel, ReuseMode::Window, &arena_w, 0.025);
        let (gap, moved) = model_gap(&off, &win);
        assert!(moved > 1e-4, "{kernel}: model did not move ({moved})");
        assert!(
            gap == 0.0,
            "{kernel}: --reuse window drifted from off by {gap}"
        );
    }
    unpin_simd_leg();
}

/// `--reuse sentence` on one thread: the run driver's only semantic
/// delta vs processing the same arena with `--reuse off` is the
/// deferred input-row scatter inside a run (an input repeating across a
/// run's windows reads pre-run state).  At small lr that is a
/// near-equality, bounded well below total movement — for both kernels.
#[test]
fn sentence_reuse_stays_equivalent_single_thread() {
    if !pin_simd_leg() {
        return;
    }
    let vc = vocab();
    let sampler = UnigramSampler::alias(&vc, 0.75);
    let arena = reuse_arena(&sampler, ReuseMode::Sentence);
    let lr = 0.01f32;
    for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
        let reference = run_reuse(kernel, ReuseMode::Off, &arena, lr);
        let reused = run_reuse(kernel, ReuseMode::Sentence, &arena, lr);
        let (gap, moved) = model_gap(&reference, &reused);
        assert!(moved > 1e-4, "{kernel}: model did not move ({moved})");
        assert!(
            gap < 5e-3,
            "{kernel}: sentence reuse drifted by {gap} (deferral only)"
        );
        assert!(
            gap < moved,
            "{kernel}: drift {gap} not small vs movement {moved}"
        );
    }
    unpin_simd_leg();
}
