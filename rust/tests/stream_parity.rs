//! Streaming-trainer acceptance suite (the stream PR's tier-1 gate).
//!
//! 1. A frozen-vocabulary stream over a file that NEVER grows is
//!    BITWISE identical to the batch trainer on the same bytes — for
//!    both GEMM kernel organisations, against the batch run's
//!    `--corpus-cache` path (itself pinned bitwise-equal to text by
//!    `corpus_parity`).  Streaming is a strict generalisation of batch
//!    training, not a different trainer.
//! 2. A stream killed mid-run and `--resume`d from its two-slot
//!    checkpoint is BITWISE identical to the uninterrupted stream over
//!    the same growth schedule: the checkpoint replays from a superbatch
//!    flush boundary, and the gemm backend is stateless between
//!    flushes.
//! 3. A run with planted LATE words — held out of the cold-start seed
//!    and fed only through growth — admits them into reserve rows and
//!    still clears the `quality_regression` Spearman floor, with the
//!    late words resolving in the final vocabulary.

use std::io::Write;
use std::path::{Path, PathBuf};

use pw2v::config::{Backend, CorpusCacheMode, KernelMode};
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::eval;
use pw2v::serve::RowStore;
use pw2v::stream::ckpt::sidecar_path;
use pw2v::train;
use pw2v::{
    EncodedCorpus, SharedModel, StreamOptions, StreamTrainer, TrainConfig, Vocab,
};

/// Same floor as `quality_regression` (chance rho is ~0).
const RHO_FLOOR: f64 = 15.0;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pw2v_stream_{}_{name}", std::process::id()))
}

fn append(path: &Path, text: &str) {
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(path)
        .unwrap();
    f.write_all(text.as_bytes()).unwrap();
}

fn stream_cfg(kernel: KernelMode) -> TrainConfig {
    let mut cfg = TrainConfig::test_tiny();
    cfg.backend = Backend::Gemm;
    cfg.kernel = kernel;
    cfg.threads = 1;
    cfg.epochs = 1;
    cfg.sample = 1e-3; // exercise the subsampler on both paths
    cfg.seed = 99;
    cfg
}

fn synthetic_text(seed: u64, tokens: usize) -> String {
    let mut scfg = SyntheticConfig::test_tiny();
    scfg.tokens = tokens;
    scfg.seed = seed;
    let lm = LatentModel::new(scfg);
    let path = tmp(&format!("gen_{seed}.txt"));
    lm.write_corpus(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    text
}

fn assert_models_bitwise(a: &SharedModel, b: &SharedModel, rows: usize, tag: &str) {
    for r in 0..rows as u32 {
        assert_eq!(a.m_in().row(r), b.m_in().row(r), "{tag}: M_in row {r}");
        assert_eq!(a.m_out().row(r), b.m_out().row(r), "{tag}: M_out row {r}");
    }
}

/// Acceptance criterion 1: frozen vocab, never-growing file, both
/// kernels — stream == batch, bit for bit.
#[test]
fn frozen_stream_matches_batch_bitwise() {
    let text = synthetic_text(71, 25_000);
    let path = tmp("frozen.txt");
    std::fs::write(&path, &text).unwrap();
    let vocab = Vocab::build_from_file(&path, 1).unwrap();
    let batch_cache = tmp("frozen.batch.u32");
    let stream_cache = tmp("frozen.stream.u32");
    let store_path = tmp("frozen.rst");
    EncodedCorpus::build(&path, &vocab, &batch_cache).unwrap();

    for kernel in [KernelMode::Gemm3, KernelMode::Fused] {
        let tag = format!("kernel {kernel}");
        let mut cfg = stream_cfg(kernel);

        cfg.corpus_cache = CorpusCacheMode::Path(batch_cache.clone());
        let batch_model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        let batch_out = train::train(&cfg, &path, &vocab, &batch_model).unwrap();

        cfg.corpus_cache = CorpusCacheMode::Path(stream_cache.clone());
        let opts = StreamOptions {
            store: Some(store_path.clone()),
            ..StreamOptions::default()
        };
        let mut tr = StreamTrainer::open(&cfg, &path, opts).unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        assert!(tr.poll_once(len).unwrap(), "{tag}: nothing consumed");
        let out = tr.finish().unwrap();

        assert_eq!(
            batch_out.snapshot.words, out.snapshot.words,
            "{tag}: word accounting"
        );
        assert_eq!(out.trained_bytes, len, "{tag}: cursor at EOF");
        assert_eq!(out.admitted, 0, "{tag}: frozen vocab admits nothing");
        assert_models_bitwise(&batch_model, tr.model(), vocab.len(), &tag);

        // The lazily synced cache must cover exactly the trained bytes
        // and open under the same vocabulary.
        let enc = EncodedCorpus::open(&stream_cache, &vocab).unwrap();
        assert_eq!(enc.text_len(), len, "{tag}: cache covers the corpus");
        // Finish without a checkpoint base still exports the store, at
        // generation 0 (no checkpoint was ever taken).
        let st = RowStore::open(&store_path).unwrap();
        assert_eq!(st.n_rows(), vocab.len(), "{tag}: store rows");
        assert_eq!(st.generation(), 0, "{tag}: store generation");

        std::fs::remove_file(&stream_cache).ok();
        std::fs::remove_file(&store_path).ok();
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&batch_cache).ok();
}

/// Acceptance criterion 2: kill + `--resume` is bitwise identical to
/// the uninterrupted stream over the same growth schedule.
#[test]
fn killed_and_resumed_stream_matches_uninterrupted() {
    let text = synthetic_text(72, 25_000);
    let lines: Vec<&str> = text.lines().collect();
    let split = lines.len() * 3 / 5;
    let seed_part: String = lines[..split]
        .iter()
        .map(|l| format!("{l}\n"))
        .collect();
    let growth_part: String = lines[split..]
        .iter()
        .map(|l| format!("{l}\n"))
        .collect();

    let cfg = stream_cfg(KernelMode::Fused);
    let run = |name: &str, kill: bool| -> (SharedModel, u64, f32) {
        let path = tmp(&format!("resume_{name}.txt"));
        let base = tmp(&format!("resume_{name}.ckpt"));
        std::fs::write(&path, &seed_part).unwrap();
        let seed_len = std::fs::metadata(&path).unwrap().len();
        let opts = StreamOptions {
            checkpoint: Some(base.clone()),
            ckpt_every: 1,
            ..StreamOptions::default()
        };
        let mut tr = StreamTrainer::open(&cfg, &path, opts.clone()).unwrap();
        tr.poll_once(seed_len).unwrap();
        if kill {
            // Superbatches flushed (and checkpointed) during the seed
            // segment; the un-flushed ragged tail past the last
            // checkpoint is what a real kill discards and replays.
            assert!(tr.snapshot().calls > 0, "seed part too small to flush");
            assert!(sidecar_path(&base).exists(), "no checkpoint before kill");
            drop(tr);
            append(&path, &growth_part);
            let opts = StreamOptions {
                resume: true,
                ..opts
            };
            tr = StreamTrainer::open(&cfg, &path, opts).unwrap();
        } else {
            append(&path, &growth_part);
        }
        let len = std::fs::metadata(&path).unwrap().len();
        tr.poll_once(len).unwrap();
        let out = tr.finish().unwrap();
        let words = out.snapshot.words;
        let lr = out.final_lr;
        let model = SharedModel::new(tr.model().m_in().clone(), tr.model().m_out().clone());
        for p in [&path, &sidecar_path(&base)] {
            std::fs::remove_file(p).ok();
        }
        for slot in 0..2 {
            std::fs::remove_file(pw2v::model::io::checkpoint_slot_path(&base, 0, slot)).ok();
        }
        (model, words, lr)
    };

    let (ref_model, ref_words, ref_lr) = run("ref", false);
    let (res_model, res_words, res_lr) = run("kill", true);
    assert_eq!(ref_words, res_words, "word accounting across kill/resume");
    assert_eq!(ref_lr.to_bits(), res_lr.to_bits(), "final lr");
    assert_eq!(ref_model.vocab(), res_model.vocab());
    assert_models_bitwise(&ref_model, &res_model, ref_model.vocab(), "kill/resume");
}

/// Acceptance criterion 3: planted late words stream in through growth,
/// get admitted into reserve rows, and the run still clears the
/// `quality_regression` Spearman floor.
#[test]
fn admission_run_clears_quality_floor_with_planted_late_words() {
    let scfg = SyntheticConfig {
        vocab: 2_000,
        tokens: 300_000,
        clusters: 20,
        beta: 5.0,
        seed: 29,
        ..SyntheticConfig::default()
    };
    let latent = LatentModel::new(scfg);
    let path = tmp("admit.txt");
    latent.write_corpus(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();

    // Plant the late words: pick moderately rare tokens, then hold every
    // line containing one of them out of the cold-start seed.
    let full_vocab = Vocab::build_from_file(&path, 1).unwrap();
    let mut late: Vec<&str> = (0..full_vocab.len() as u32)
        .map(|i| full_vocab.word(i))
        .filter(|w| {
            let c = full_vocab.counts()[full_vocab.id(w).unwrap() as usize];
            (3..=30).contains(&c)
        })
        .take(12)
        .collect();
    assert!(late.len() >= 8, "fixture has too few rare words to plant");
    let is_late_line =
        |l: &str| l.split_ascii_whitespace().any(|t| late.contains(&t));
    let seed_part: String = text
        .lines()
        .filter(|l| !is_late_line(l))
        .map(|l| format!("{l}\n"))
        .collect();
    let growth_lines: Vec<&str> = text.lines().filter(|l| is_late_line(l)).collect();
    assert!(!growth_lines.is_empty());
    std::fs::write(&path, &seed_part).unwrap();

    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::Gemm;
    cfg.kernel = KernelMode::Fused;
    cfg.threads = 1;
    cfg.epochs = 1;
    cfg.dim = 48;
    cfg.sample = 1e-3;
    cfg.lr = 0.05;
    // Admission threshold 1: a planted word is due after its first
    // observed occurrence (their full-corpus counts go as low as 3).
    cfg.min_count = 1;
    cfg.vocab_reserve = 256;
    let mut tr = StreamTrainer::open(&cfg, &path, StreamOptions::default()).unwrap();
    let cold_len = tr.vocab().len();
    for w in &late {
        assert!(tr.vocab().id(w).is_none(), "{w} leaked into the seed vocab");
    }
    tr.poll_once(std::fs::metadata(&path).unwrap().len()).unwrap();

    // Feed the held-out lines in chunks, polling between chunks so words
    // admitted from one chunk train on the occurrences in the next.
    for chunk in growth_lines.chunks(growth_lines.len().div_ceil(10).max(1)) {
        let mut s = String::new();
        for l in chunk {
            s.push_str(l);
            s.push('\n');
        }
        append(&path, &s);
        tr.poll_once(std::fs::metadata(&path).unwrap().len()).unwrap();
    }
    // One idle poll so candidates from the final chunk can be admitted.
    tr.poll_once(std::fs::metadata(&path).unwrap().len()).unwrap();
    let out = tr.finish().unwrap();

    assert!(
        out.admitted >= late.len() as u64,
        "only {} admissions for {} planted words",
        out.admitted,
        late.len()
    );
    assert!(out.vocab_len > cold_len, "vocab never grew");
    for w in &late {
        assert!(
            tr.vocab().id(w).is_some(),
            "planted word {w} was never admitted"
        );
    }

    let sim_set = eval::gen_similarity_set(&latent, 200, 3);
    let sim = eval::eval_similarity(&sim_set, tr.vocab(), tr.model().m_in());
    assert!(
        sim.pairs_covered > 150,
        "similarity coverage {}/{}",
        sim.pairs_covered,
        sim.pairs_total
    );
    assert!(
        sim.rho100 > RHO_FLOOR,
        "rho100 {:.1} below quality floor {RHO_FLOOR} after admission run",
        sim.rho100
    );
    std::fs::remove_file(&path).ok();
}
