//! NUMA-path parity: `--numa off` (flat model, unpinned workers — the
//! pre-NUMA path bit-for-bit) versus the sharded store (`--numa auto` /
//! `--numa <nodes>`).
//!
//! The sharded layout changes WHERE rows live (per-node segments,
//! first-touched by pinned threads) but never what they hold, so:
//!
//! * at 1 worker thread training is deterministic and the two paths must
//!   be BITWISE equal, for both kernel organisations and any node count
//!   (including more nodes than the machine has);
//! * at several worker threads Hogwild races make every run (flat or
//!   sharded) nondeterministic; the suite bounds the drift with the same
//!   gap-vs-movement machinery as `tests/backend_parity.rs`;
//! * the distributed replica protocol is deterministic per node count
//!   (disjoint replicas, barrier-ordered allreduce), so single-node
//!   `--numa auto` (replica first-touch-initialised by its own pinned
//!   thread) must be bitwise equal to `--numa off` too.
//!
//! The CI matrix reruns this file under `PW2V_TOPOLOGY=0;0` (a synthetic
//! two-node topology on a one-node runner) and pinned-scalar dispatch,
//! so `--numa auto` legs exercise real multi-node sharding geometry.

use pw2v::config::KernelMode;
use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::dist::{train_distributed, DistConfig};
use pw2v::SharedModel;
use pw2v::runtime::topology::NumaMode;
use pw2v::train;

mod common;

fn tiny_corpus(seed: u64) -> (std::path::PathBuf, Vocab) {
    let mut scfg = SyntheticConfig::test_tiny();
    scfg.tokens = 30_000;
    scfg.seed = seed;
    let lm = LatentModel::new(scfg);
    let path = std::env::temp_dir().join(format!(
        "pw2v_numa_parity_{seed}_{}.txt",
        std::process::id()
    ));
    lm.write_corpus(&path).unwrap();
    let vocab = Vocab::build_from_file(&path, 1).unwrap();
    (path, vocab)
}

fn train_with(
    cfg: &TrainConfig,
    path: &std::path::Path,
    vocab: &Vocab,
) -> (SharedModel, u64) {
    let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
    let out = train::train(cfg, path, vocab, &model).unwrap();
    (model, out.snapshot.words)
}

/// Shared drift-vs-movement machinery (`tests/common/mod.rs`) bound to
/// this suite's per-config geometry.
fn model_gap(a: &SharedModel, b: &SharedModel, cfg: &TrainConfig) -> (f64, f64) {
    common::model_gap(a, b, a.vocab(), cfg.dim, cfg.seed)
}

/// One worker thread: flat vs sharded must be BITWISE identical for both
/// kernels and for every sharding geometry — auto (whatever this machine
/// or `PW2V_TOPOLOGY` says), two synthetic nodes, and a node count
/// chosen to leave some shards tiny.
#[test]
fn single_thread_bitwise_across_numa_modes() {
    let (path, vocab) = tiny_corpus(71);
    for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
        let mut cfg = TrainConfig::test_tiny();
        cfg.kernel = kernel;
        cfg.sample = 0.0;
        cfg.numa = NumaMode::Off;
        let (flat, flat_words) = train_with(&cfg, &path, &vocab);
        assert_eq!(flat_words, vocab.total_words());
        for numa in [NumaMode::Auto, NumaMode::Nodes(2), NumaMode::Nodes(7)] {
            cfg.numa = numa;
            let (sharded, words) = train_with(&cfg, &path, &vocab);
            assert_eq!(words, flat_words, "{kernel}/{numa}: word accounting");
            assert_eq!(
                flat.m_in().data(),
                sharded.m_in().data(),
                "{kernel}/{numa}: M_in diverged from the flat path"
            );
            assert_eq!(
                flat.m_out().data(),
                sharded.m_out().data(),
                "{kernel}/{numa}: M_out diverged from the flat path"
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Multi-threaded: Hogwild races make each run nondeterministic, flat
/// and sharded alike; the sharded path must stay within the same
/// race-noise envelope (drift well below signal), with full word
/// accounting.
#[test]
fn multithreaded_drift_is_bounded() {
    let (path, vocab) = tiny_corpus(73);
    let mut cfg = TrainConfig::test_tiny();
    cfg.threads = 4;
    cfg.sample = 0.0;
    cfg.numa = NumaMode::Off;
    let (flat, words_off) = train_with(&cfg, &path, &vocab);
    assert_eq!(words_off, vocab.total_words());
    for numa in [NumaMode::Auto, NumaMode::Nodes(2)] {
        cfg.numa = numa;
        let (sharded, words) = train_with(&cfg, &path, &vocab);
        assert_eq!(words, words_off, "{numa}: word accounting");
        let (gap, moved) = model_gap(&flat, &sharded, &cfg);
        assert!(moved > 1e-4, "{numa}: model did not move ({moved})");
        assert!(
            gap < moved,
            "{numa}: flat vs sharded drift {gap} not below movement {moved}"
        );
    }
    std::fs::remove_file(&path).ok();
}

/// Distributed, one node: the replica protocol is single-threaded and
/// deterministic, so `--numa auto` (replica allocated untouched and
/// first-touch-initialised inside its own pinned thread) must reproduce
/// `--numa off` (main-thread `SharedModel::init`) bitwise.
#[test]
fn dist_single_node_numa_is_bitwise() {
    let (path, vocab) = tiny_corpus(79);
    let mut cfg = TrainConfig::test_tiny();
    cfg.sample = 0.0;
    let mut dist = DistConfig::for_nodes(1);
    dist.sync_interval = 8_000;
    cfg.numa = NumaMode::Off;
    let off = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
    cfg.numa = NumaMode::Auto;
    let auto = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
    assert_eq!(off.words, auto.words);
    assert_eq!(off.model.m_in().data(), auto.model.m_in().data());
    assert_eq!(off.model.m_out().data(), auto.model.m_out().data());
    std::fs::remove_file(&path).ok();
}

/// Distributed, several replicas under NUMA: every replica becomes
/// node-local (pinned init + training) and the protocol still accounts
/// every word, joins the same number of rounds on every node, and moves
/// the merged model.
#[test]
fn dist_replicas_train_under_numa() {
    let (path, vocab) = tiny_corpus(83);
    let mut cfg = TrainConfig::test_tiny();
    cfg.sample = 0.0;
    cfg.numa = NumaMode::Nodes(2);
    let mut dist = DistConfig::for_nodes(3);
    dist.sync_interval = 4_000;
    let out = train_distributed(&cfg, &dist, &path, &vocab).unwrap();
    assert_eq!(out.words, vocab.total_words());
    let rounds = out.sync_stats[0].rounds;
    for st in &out.sync_stats {
        assert_eq!(st.rounds, rounds);
    }
    let init = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
    assert_ne!(out.model.m_in().data(), init.m_in().data());
    std::fs::remove_file(&path).ok();
}
