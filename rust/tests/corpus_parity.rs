//! Streaming/encoded corpus parity suite (the corpus-cache PR's
//! acceptance gate).
//!
//! 1. For randomized corpora full of ingest edge cases — empty and
//!    whitespace-only lines, OOV runs, lines past `MAX_SENTENCE_LEN`,
//!    multi-byte (non-ASCII) whitespace glued into tokens, missing final
//!    newline — the [`EncodedSentenceReader`] must yield BIT-IDENTICAL
//!    sentence sequences to the streaming [`SentenceReader`], whole-file
//!    and shard-by-shard for every split in {2, 3, 7}.
//! 2. A seeded single-thread end-to-end train must produce bitwise-equal
//!    embeddings on the text vs the cached corpus, for both `--kernel
//!    gemm3` and `fused` — and (in debug builds) perform ZERO vocab hash
//!    lookups while training from the cache.
//! 3. Invalid caches — wrong magic/version, truncation, stale vocab
//!    fingerprint, zero sentences — are rejected, and `auto` mode
//!    preserves the corrupt file as `.bak` and rebuilds instead of
//!    feeding garbage to the trainer.

use std::path::{Path, PathBuf};

use pw2v::config::{CorpusCacheMode, KernelMode};
use pw2v::TrainConfig;
use pw2v::corpus::encoded::{CACHE_SUFFIX, MAGIC};
use pw2v::EncodedCorpus;
use pw2v::corpus::reader::SentenceReader;
use pw2v::corpus::shard::shards_for_len;
use pw2v::corpus::source::Corpus;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::corpus::MAX_SENTENCE_LEN;
use pw2v::SharedModel;
use pw2v::train;
use pw2v::util::rng::Xoshiro256ss;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pw2v_parity_{}_{name}", std::process::id()))
}

fn write_file(name: &str, content: &str) -> PathBuf {
    let path = tmp(name);
    std::fs::write(&path, content).unwrap();
    path
}

fn with_suffix(p: &Path, suffix: &str) -> PathBuf {
    let mut os = p.as_os_str().to_os_string();
    os.push(suffix);
    PathBuf::from(os)
}

/// Vocabulary the nasty corpora are read under: w0..w19 only, so every
/// other token (OOV markers, multibyte-whitespace-glued pairs) drops.
fn small_vocab() -> Vocab {
    Vocab::build((0..20).map(|i| format!("w{i}")), 1)
}

/// A corpus built to hit every ingest edge at once.
fn nasty_corpus(seed: u64) -> String {
    let mut rng = Xoshiro256ss::new(seed);
    let mut s = String::new();
    // Guarantee at least one retained sentence whatever the dice say.
    s.push_str("w1 w2 w3\n");
    let lines = 40 + rng.below(60);
    for _ in 0..lines {
        match rng.below(10) {
            0 => s.push('\n'),                   // empty line
            1 => s.push_str(" \t  \n"),          // whitespace-only line
            2 => {
                // Pure OOV run: the line must vanish from both streams.
                for _ in 0..1 + rng.below(5) {
                    s.push_str("OOVTOKEN ");
                }
                s.push('\n');
            }
            3 => {
                // Longer than MAX_SENTENCE_LEN: both readers clip.
                for i in 0..MAX_SENTENCE_LEN + 50 {
                    s.push_str(&format!("w{} ", i % 20));
                }
                s.push('\n');
            }
            4 => {
                // Multi-byte whitespace (U+00A0, U+2009) is NOT ASCII
                // whitespace: it glues neighbours into one OOV token.
                s.push_str("w1\u{00A0}w2 w3\u{2009}w4 w5\n");
            }
            _ => {
                for _ in 0..1 + rng.below(12) {
                    // ~1 in 6 tokens is OOV inside an otherwise good line.
                    if rng.below(6) == 0 {
                        s.push_str("ZZZ ");
                    } else {
                        s.push_str(&format!("w{} ", rng.below(20)));
                    }
                }
                s.push('\n');
            }
        }
    }
    if rng.below(3) == 0 {
        // Final line without '\n'.
        s.push_str("w4 w5 w6");
    }
    s
}

fn collect_text(path: &Path, vocab: &Vocab, start: u64, end: u64) -> Vec<Vec<u32>> {
    SentenceReader::open_range(path, vocab, start, end)
        .unwrap()
        .collect_sentences()
        .unwrap()
}

#[test]
fn encoded_matches_streaming_across_shard_splits() {
    let vocab = small_vocab();
    for seed in [1u64, 2, 3, 5, 8, 13, 2026] {
        let path = write_file(&format!("shards_{seed}.txt"), &nasty_corpus(seed));
        let cache = with_suffix(&path, CACHE_SUFFIX);
        EncodedCorpus::build(&path, &vocab, &cache).unwrap();
        let enc = EncodedCorpus::open(&cache, &vocab).unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        assert_eq!(enc.text_len(), len);

        let whole_text = collect_text(&path, &vocab, 0, len);
        assert!(!whole_text.is_empty());
        let whole_enc = enc.reader().collect_sentences().unwrap();
        assert_eq!(whole_enc, whole_text, "seed {seed}: whole-file parity");

        for nshards in [2usize, 3, 7] {
            let mut text_all = Vec::new();
            let mut enc_all = Vec::new();
            for sh in shards_for_len(len, nshards) {
                let t = collect_text(&path, &vocab, sh.start, sh.end);
                let e = enc
                    .reader_range(sh.start, sh.end)
                    .collect_sentences()
                    .unwrap();
                assert_eq!(
                    e, t,
                    "seed {seed}: shard {}/{nshards} [{}, {}) diverges",
                    sh.index, sh.start, sh.end
                );
                text_all.extend(t);
                enc_all.extend(e);
            }
            // The shard union must also be lossless and duplication-free
            // on BOTH paths (this is what the boundary fix buys).
            assert_eq!(text_all, whole_text, "seed {seed}: text {nshards}-way");
            assert_eq!(enc_all, whole_text, "seed {seed}: encoded {nshards}-way");
        }
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&cache).ok();
    }
}

/// Adversarial split sweep on a tiny corpus: EVERY byte is a split point,
/// so every line boundary lands exactly on a shard edge at least once.
#[test]
fn encoded_matches_streaming_at_every_split_point() {
    let vocab = small_vocab();
    let content = "w1 w2\n\nw3\nOOVTOKEN\nw4 w5 w1\nw2";
    let path = write_file("everysplit.txt", content);
    let cache = with_suffix(&path, CACHE_SUFFIX);
    EncodedCorpus::build(&path, &vocab, &cache).unwrap();
    let enc = EncodedCorpus::open(&cache, &vocab).unwrap();
    let len = content.len() as u64;
    let whole = collect_text(&path, &vocab, 0, len);
    for split in 0..=len {
        let mut text_parts = collect_text(&path, &vocab, 0, split);
        text_parts.extend(collect_text(&path, &vocab, split, len));
        let mut enc_parts = enc.reader_range(0, split).collect_sentences().unwrap();
        enc_parts.extend(enc.reader_range(split, len).collect_sentences().unwrap());
        assert_eq!(text_parts, whole, "text split at byte {split}");
        assert_eq!(enc_parts, whole, "encoded split at byte {split}");
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cache).ok();
}

fn tiny_synthetic(seed: u64) -> (PathBuf, Vocab) {
    let mut scfg = SyntheticConfig::test_tiny();
    scfg.tokens = 25_000;
    scfg.seed = seed;
    let lm = LatentModel::new(scfg);
    let path = tmp(&format!("train_{seed}.txt"));
    lm.write_corpus(&path).unwrap();
    let vocab = Vocab::build_from_file(&path, 1).unwrap();
    (path, vocab)
}

/// The end-to-end acceptance criterion: a seeded single-thread train is
/// BITWISE identical between the text path and the cached path, for both
/// kernel organisations — and the cached run never hashes a token.
#[test]
fn cached_training_is_bitwise_identical_to_text() {
    let (path, vocab) = tiny_synthetic(71);
    let cache = with_suffix(&path, ".cache.u32");
    // Build once up front so the lookup snapshot below excludes the
    // (one-time) encoding pass.
    EncodedCorpus::build(&path, &vocab, &cache).unwrap();
    for kernel in [KernelMode::Gemm3, KernelMode::Fused] {
        let mut cfg = TrainConfig::test_tiny();
        cfg.backend = pw2v::config::Backend::Gemm;
        cfg.kernel = kernel;
        cfg.threads = 1;
        cfg.epochs = 2;
        cfg.sample = 1e-3; // exercise the subsampler on both paths
        cfg.seed = 99;

        cfg.corpus_cache = CorpusCacheMode::Off;
        let text_model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        let text_out = train::train(&cfg, &path, &vocab, &text_model).unwrap();

        cfg.corpus_cache = CorpusCacheMode::Path(cache.clone());
        let lookups_before = vocab.id_lookups();
        let enc_model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
        let enc_out = train::train(&cfg, &path, &vocab, &enc_model).unwrap();

        assert_eq!(
            text_out.snapshot.words, enc_out.snapshot.words,
            "kernel {kernel}: word accounting"
        );
        assert_eq!(
            text_model.m_in().data(),
            enc_model.m_in().data(),
            "kernel {kernel}: M_in must be bitwise identical"
        );
        assert_eq!(
            text_model.m_out().data(),
            enc_model.m_out().data(),
            "kernel {kernel}: M_out must be bitwise identical"
        );
        if cfg!(debug_assertions) {
            assert_eq!(
                vocab.id_lookups(),
                lookups_before,
                "kernel {kernel}: cached training must perform zero vocab \
                 hash lookups (every epoch, not just epoch >= 2)"
            );
        }
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cache).ok();
}

/// Every corruption class is detected at open, with a diagnosable error.
#[test]
fn cache_invalidation_rejects_every_corruption_class() {
    let vocab = small_vocab();
    let path = write_file("inval.txt", "w1 w2 w3\nw4 w5\n");
    let cache = with_suffix(&path, CACHE_SUFFIX);
    EncodedCorpus::build(&path, &vocab, &cache).unwrap();
    let good = std::fs::read(&cache).unwrap();
    let expect_err = |bytes: &[u8], needle: &str| {
        std::fs::write(&cache, bytes).unwrap();
        let err = EncodedCorpus::open(&cache, &vocab).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(needle), "want '{needle}' in: {msg}");
    };

    // Wrong magic.
    let mut bad = good.clone();
    bad[0] ^= 0xFF;
    expect_err(&bad, "magic");
    // Unsupported version.
    let mut bad = good.clone();
    bad[8] = 99;
    expect_err(&bad, "version");
    // Truncated body.
    expect_err(&good[..good.len() - 5], "truncated");
    // Truncated below even the header.
    expect_err(&good[..20], "truncated");
    // Stale vocab fingerprint (flip one digest byte).
    let mut bad = good.clone();
    bad[16] ^= 0x01;
    expect_err(&bad, "fingerprint");
    // Zero sentences: a structurally valid, empty cache.
    let mut empty = good[..48].to_vec();
    empty[32..40].fill(0); // n_sentences = 0
    empty[40..48].fill(0); // n_tokens = 0
    empty.extend_from_slice(&0u64.to_le_bytes()); // starts = [0]
    expect_err(&empty, "zero sentences");
    // Out-of-range ids: the builder records the payload's max id in the
    // header (bytes 12..16) so `open` bound-checks the whole stream in
    // O(1); a max id at/past the vocab length must be rejected.
    let mut bad = good.clone();
    bad[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
    expect_err(&bad, "out of range");

    // A stale cache is also rejected when read through a DIFFERENT vocab
    // than it was built under (the satellite's headline case).
    std::fs::write(&cache, &good).unwrap();
    let other = Vocab::build((0..21).map(|i| format!("w{i}")), 1);
    let err = EncodedCorpus::open(&cache, &other).unwrap_err();
    assert!(format!("{err:#}").contains("fingerprint"));

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cache).ok();
}

/// `auto` mode turns every rejection into a rebuild: the corrupt file is
/// preserved as `.bak` (the BENCH_throughput.json discipline) and the
/// rebuilt cache trains cleanly.
#[test]
fn auto_mode_rebuilds_corrupt_cache_and_preserves_bak() {
    let vocab = small_vocab();
    let path = write_file("rebuild.txt", "w1 w2\nw3 w4 w5\n");
    let cache = with_suffix(&path, CACHE_SUFFIX);
    let bak = with_suffix(&cache, ".bak");
    std::fs::remove_file(&bak).ok();

    // Corrupt "cache" left by some earlier failure.
    std::fs::write(&cache, b"definitely not a cache").unwrap();
    let corpus = Corpus::open(&path, &vocab, &CorpusCacheMode::Auto).unwrap();
    assert!(corpus.is_encoded());
    assert_eq!(
        std::fs::read(&bak).unwrap(),
        b"definitely not a cache",
        "corrupt cache must be preserved, not clobbered"
    );
    // The rebuilt cache matches the text stream.
    let len = std::fs::metadata(&path).unwrap().len();
    let mut reader = corpus.open_range(0, len).unwrap();
    let mut sent = Vec::new();
    let mut got = Vec::new();
    while reader.next_sentence_into(&mut sent).unwrap() {
        got.push(sent.clone());
    }
    assert_eq!(got, collect_text(&path, &vocab, 0, len));

    // Stale-vocab rebuild: reuse the same file under a grown vocabulary.
    let grown = Vocab::build((0..25).map(|i| format!("w{i}")), 1);
    let corpus = Corpus::open(&path, &grown, &CorpusCacheMode::Auto).unwrap();
    assert!(corpus.is_encoded());
    let enc = EncodedCorpus::open(&cache, &grown).unwrap();
    assert_eq!(enc.n_sentences(), 2);

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cache).ok();
    std::fs::remove_file(&bak).ok();
}

/// A same-length, same-vocabulary rewrite of the corpus (the classic
/// case: shuffling lines between epochs' runs) defeats both the length
/// check and the fingerprint — the mtime rule must catch it.
#[test]
fn auto_mode_rebuilds_when_source_is_rewritten_same_length() {
    let vocab = small_vocab();
    let path = write_file("shuffle.txt", "w1 w2\nw3 w4\n");
    let cache = with_suffix(&path, CACHE_SUFFIX);
    let (enc, built) = EncodedCorpus::ensure(&path, &vocab, &cache).unwrap();
    assert!(built);
    let first = enc.reader().collect_sentences().unwrap();
    drop(enc);
    // Same byte length, same token multiset (fingerprint is built from
    // the vocab, which is fixed here), different ORDER.  Sleep past
    // coarse filesystem mtime granularity so the rewrite is strictly
    // newer than the cache.
    std::thread::sleep(std::time::Duration::from_millis(1100));
    std::fs::write(&path, "w3 w4\nw1 w2\n").unwrap();
    let (enc, built) = EncodedCorpus::ensure(&path, &vocab, &cache).unwrap();
    assert!(built, "same-length rewrite must invalidate via mtime");
    let second = enc.reader().collect_sentences().unwrap();
    assert_ne!(first, second, "rebuilt cache must reflect the new order");
    assert_eq!(second, collect_text(&path, &vocab, 0, 12));
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cache).ok();
    std::fs::remove_file(with_suffix(&cache, ".bak")).ok();
}

/// MAGIC is part of the public format contract; pin it so a refactor
/// cannot silently orphan existing caches.
#[test]
fn format_magic_is_stable() {
    assert_eq!(&MAGIC, b"PW2VU32\0");
    assert_eq!(CACHE_SUFFIX, ".pw2v.u32");
}
