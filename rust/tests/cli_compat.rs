//! CLI-compatibility contract, pinned over the real binary (cargo sets
//! `CARGO_BIN_EXE_pw2v` for integration tests):
//!
//! - every subcommand — the pre-split set AND the new `encode`/`stream`
//!   — answers `--help` with its own usage block;
//! - bare `pw2v <corpus>` still works as an alias for
//!   `train --corpus <corpus>` (the original single-purpose invocation);
//! - unknown subcommands are rejected with a diagnostic;
//! - errors name the subcommand that produced them.

use std::path::PathBuf;
use std::process::{Command, Output};

const SUBCOMMANDS: &[&str] = &[
    "gen-corpus",
    "encode",
    "train",
    "train-dist",
    "stream",
    "eval",
    "serve",
    "simulate",
    "info",
];

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_pw2v"))
}

fn run(args: &[&str]) -> Output {
    bin().args(args).output().expect("spawn pw2v")
}

fn stdout(o: &Output) -> String {
    String::from_utf8_lossy(&o.stdout).into_owned()
}

fn stderr(o: &Output) -> String {
    String::from_utf8_lossy(&o.stderr).into_owned()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("pw2v_cli_{}_{name}", std::process::id()))
}

#[test]
fn top_level_help_lists_every_subcommand() {
    for invocation in [&[][..], &["help"][..], &["--help"][..]] {
        let o = run(invocation);
        assert!(o.status.success(), "{invocation:?}: {}", stderr(&o));
        let out = stdout(&o);
        for name in SUBCOMMANDS {
            assert!(out.contains(name), "{invocation:?} help lacks {name}");
        }
    }
}

#[test]
fn every_subcommand_answers_help_with_its_own_usage() {
    for name in SUBCOMMANDS {
        let o = run(&[name, "--help"]);
        assert!(o.status.success(), "{name} --help failed: {}", stderr(&o));
        let out = stdout(&o);
        assert!(
            out.contains(&format!("USAGE: pw2v {name}")),
            "{name} --help does not lead with its usage:\n{out}"
        );
    }
    // The training-family helps carry the shared flag table.
    for name in ["train", "train-dist", "stream"] {
        let out = stdout(&run(&[name, "--help"]));
        for flag in ["--simd", "--corpus-cache", "--numa"] {
            assert!(out.contains(flag), "{name} --help lacks shared flag {flag}");
        }
    }
}

#[test]
fn bare_corpus_invocation_aliases_to_train() {
    let corpus = tmp("alias.txt");
    let vectors = tmp("alias.vec");
    let mut text = String::new();
    for i in 0..120 {
        text.push_str(&format!("w{} w{} w{} w{}\n", i % 7, (i + 1) % 7, (i + 2) % 7, i % 5));
    }
    std::fs::write(&corpus, text).unwrap();

    let o = run(&[
        corpus.to_str().unwrap(),
        "--backend",
        "scalar",
        "--dim",
        "32",
        "--epochs",
        "1",
        "--threads",
        "1",
        "--out",
        vectors.to_str().unwrap(),
    ]);
    assert!(o.status.success(), "alias run failed: {}", stderr(&o));
    assert!(
        stderr(&o).contains("training:"),
        "alias did not reach the trainer: {}",
        stderr(&o)
    );
    let saved = std::fs::read_to_string(&vectors).unwrap();
    assert!(saved.starts_with("7 32"), "unexpected vector header: {saved:.20}");
    std::fs::remove_file(&corpus).ok();
    std::fs::remove_file(&vectors).ok();
}

#[test]
fn unknown_subcommand_is_rejected_with_a_diagnostic() {
    let o = run(&["frobnicate"]);
    assert!(!o.status.success());
    let err = stderr(&o);
    assert!(
        err.contains("unknown subcommand 'frobnicate'"),
        "unhelpful error: {err}"
    );
}

#[test]
fn errors_name_the_subcommand_that_produced_them() {
    // train without a corpus; stream with a forbidden backend.
    let o = run(&["train"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("pw2v train"), "{}", stderr(&o));
    assert!(stderr(&o).contains("--corpus"), "{}", stderr(&o));

    let corpus = tmp("err.txt");
    std::fs::write(&corpus, "a b c a b c\n").unwrap();
    let o = run(&["stream", corpus.to_str().unwrap(), "--backend", "scalar"]);
    assert!(!o.status.success());
    let err = stderr(&o);
    assert!(err.contains("pw2v stream"), "{err}");
    assert!(err.contains("gemm"), "{err}");
    std::fs::remove_file(&corpus).ok();
}

#[test]
fn unknown_flags_still_fail_fast_per_subcommand() {
    let o = run(&["simulate", "--figure", "3", "--typo", "1"]);
    assert!(!o.status.success());
    assert!(stderr(&o).contains("typo"), "{}", stderr(&o));
}
