//! Integration tests: full pipelines across modules — corpus generation →
//! vocabulary → training (every back-end) → evaluation → persistence, the
//! distributed sub-model sync protocol, and the CLI binary.

use std::path::PathBuf;
use std::process::Command;

use pw2v::config::Backend;
use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::dist::{train_distributed, DistConfig, SyncPolicy};
use pw2v::eval;
use pw2v::model::{io as model_io, SharedModel};
use pw2v::train;

struct Fixture {
    corpus: PathBuf,
    vocab: Vocab,
    latent: LatentModel,
}

impl Drop for Fixture {
    fn drop(&mut self) {
        std::fs::remove_file(&self.corpus).ok();
    }
}

fn fixture(tokens: u64, seed: u64) -> Fixture {
    let scfg = SyntheticConfig {
        vocab: 2_000,
        tokens,
        clusters: 20,
        beta: 5.0,
        seed,
        ..SyntheticConfig::default()
    };
    let latent = LatentModel::new(scfg);
    let corpus = std::env::temp_dir().join(format!(
        "pw2v_it_{}_{}.txt",
        seed,
        std::process::id()
    ));
    latent.write_corpus(&corpus).unwrap();
    let vocab = Vocab::build_from_file(&corpus, 1).unwrap();
    Fixture {
        corpus,
        vocab,
        latent,
    }
}

/// Every back-end must actually LEARN: similarity correlation with the
/// planted ground truth must be strongly positive after training, far
/// beyond chance.
#[test]
fn all_backends_learn_planted_semantics() {
    let f = fixture(400_000, 11);
    let sim_set = eval::gen_similarity_set(&f.latent, 200, 3);
    for backend in [Backend::Scalar, Backend::Bidmach, Backend::Gemm] {
        let mut cfg = TrainConfig::default();
        cfg.backend = backend;
        cfg.dim = 64;
        cfg.epochs = 3;
        cfg.sample = 1e-3;
        cfg.lr = 0.05;
        let model = SharedModel::init(f.vocab.len(), cfg.dim, cfg.seed);
        train::train(&cfg, &f.corpus, &f.vocab, &model).unwrap();
        let r = eval::eval_similarity(&sim_set, &f.vocab, model.m_in());
        assert!(
            r.rho100 > 30.0,
            "{backend}: rho100 = {:.1} (should be >> 0)",
            r.rho100
        );
    }
}

/// The PJRT (AOT JAX/Pallas) back-end must learn equivalently to the
/// native GEMM back-end — the whole-stack composition test.
#[test]
fn pjrt_backend_learns_like_native() {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let f = fixture(200_000, 13);
    let sim_set = eval::gen_similarity_set(&f.latent, 200, 3);
    let mut rhos = Vec::new();
    for backend in [Backend::Gemm, Backend::Pjrt] {
        let mut cfg = TrainConfig::default();
        cfg.backend = backend;
        cfg.dim = 32; // matches the test artifact D
        cfg.batch = 8;
        cfg.superbatch = 4; // matches test_w4_b8_s6_d32
        cfg.epochs = 3;
        cfg.sample = 1e-3;
        cfg.lr = 0.05;
        cfg.artifacts_dir = artifacts.to_string_lossy().into_owned();
        let model = SharedModel::init(f.vocab.len(), cfg.dim, cfg.seed);
        train::train(&cfg, &f.corpus, &f.vocab, &model).unwrap();
        let r = eval::eval_similarity(&sim_set, &f.vocab, model.m_in());
        rhos.push(r.rho100);
    }
    assert!(rhos[0] > 25.0, "native rho {:.1}", rhos[0]);
    assert!(rhos[1] > 25.0, "pjrt rho {:.1}", rhos[1]);
    assert!(
        (rhos[0] - rhos[1]).abs() < 15.0,
        "native {:.1} vs pjrt {:.1} diverge",
        rhos[0],
        rhos[1]
    );
}

/// Distributed training with sub-model sync must match single-node
/// accuracy within a small margin (Table IV's claim, miniature).
#[test]
fn distributed_matches_single_node_accuracy() {
    let f = fixture(400_000, 17);
    let sim_set = eval::gen_similarity_set(&f.latent, 200, 3);
    let mut cfg = TrainConfig::default();
    cfg.dim = 64;
    cfg.epochs = 2;
    cfg.sample = 1e-3;
    cfg.lr = 0.05;

    let model = SharedModel::init(f.vocab.len(), cfg.dim, cfg.seed);
    train::train(&cfg, &f.corpus, &f.vocab, &model).unwrap();
    let single = eval::eval_similarity(&sim_set, &f.vocab, model.m_in()).rho100;

    let mut dist = DistConfig::for_nodes(4);
    dist.sync_interval = 25_000;
    dist.policy = SyncPolicy::submodel_for_vocab(f.vocab.len());
    let out = train_distributed(&cfg, &dist, &f.corpus, &f.vocab).unwrap();
    let multi = eval::eval_similarity(&sim_set, &f.vocab, out.model.m_in()).rho100;

    assert!(single > 30.0, "single-node rho {single:.1}");
    assert!(
        multi > single - 12.0,
        "distributed rho {multi:.1} fell too far below single {single:.1}"
    );
    // Sub-model sync must have actually skipped rows.
    let full_rows_per_round = 2 * f.vocab.len() as u64;
    let st = &out.sync_stats[0];
    assert!(st.rows_synced < st.rounds * full_rows_per_round);
}

/// Save → load round trip preserves evaluation results.
#[test]
fn persistence_roundtrip_preserves_eval() {
    let f = fixture(200_000, 19);
    let sim_set = eval::gen_similarity_set(&f.latent, 150, 3);
    let mut cfg = TrainConfig::default();
    cfg.dim = 48;
    cfg.epochs = 2;
    cfg.sample = 1e-3;
    let model = SharedModel::init(f.vocab.len(), cfg.dim, cfg.seed);
    train::train(&cfg, &f.corpus, &f.vocab, &model).unwrap();
    let before = eval::eval_similarity(&sim_set, &f.vocab, model.m_in()).rho100;

    let path = std::env::temp_dir().join(format!("pw2v_it_vec_{}.txt", std::process::id()));
    model_io::save_text(&path, &f.vocab, model.m_in()).unwrap();
    let (words, emb) = model_io::load_text(&path).unwrap();
    assert_eq!(words.len(), f.vocab.len());
    let after = eval::eval_similarity(&sim_set, &f.vocab, &emb).rho100;
    assert!((before - after).abs() < 1e-6);
    std::fs::remove_file(&path).ok();
}

/// The CLI binary end to end: gen-corpus → train → eval.
#[test]
fn cli_pipeline() {
    let bin = env!("CARGO_BIN_EXE_pw2v");
    let tmp = std::env::temp_dir().join(format!("pw2v_cli_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let corpus = tmp.join("c.txt");
    let simset = tmp.join("sim.tsv");
    let vectors = tmp.join("v.txt");

    let ok = Command::new(bin)
        .args([
            "gen-corpus",
            "--out",
            corpus.to_str().unwrap(),
            "--tokens",
            "200000",
            "--vocab",
            "2000",
            "--simset",
            simset.to_str().unwrap(),
        ])
        .status()
        .unwrap();
    assert!(ok.success());

    let ok = Command::new(bin)
        .args([
            "train",
            "--corpus",
            corpus.to_str().unwrap(),
            "--out",
            vectors.to_str().unwrap(),
            "--dim",
            "48",
            "--epochs",
            "2",
            "--min-count",
            "1",
            "--sample",
            "0.001",
        ])
        .status()
        .unwrap();
    assert!(ok.success());

    let out = Command::new(bin)
        .args([
            "eval",
            "--vectors",
            vectors.to_str().unwrap(),
            "--simset",
            simset.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("rho100"), "{stdout}");

    std::fs::remove_dir_all(&tmp).ok();
}

/// `simulate` subcommand prints both figures.
#[test]
fn cli_simulate() {
    let bin = env!("CARGO_BIN_EXE_pw2v");
    for fig in ["3", "4"] {
        let out = Command::new(bin)
            .args(["simulate", "--figure", fig])
            .output()
            .unwrap();
        assert!(out.status.success());
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains(&format!("Fig {fig}")), "{stdout}");
    }
}
