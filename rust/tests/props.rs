//! Property-based tests (randomised invariants; proptest is not vendored
//! offline, so cases are driven by the crate's own deterministic RNG —
//! hundreds of random cases per property, seed-reproducible).

use pw2v::corpus::shard::{shards_for_len, subshards};
use pw2v::eval::spearman::spearman;
use pw2v::linalg::simd::{self, SimdMode};
use pw2v::linalg::{dot, gemm_nn, gemm_nt, gemm_tn};
use pw2v::SharedModel;
use pw2v::sampling::batch::Window;
use pw2v::train::sgd_gemm::GemmBackend;
use pw2v::train::Backend;
use pw2v::util::json::Json;
use pw2v::util::rng::Xoshiro256ss;

fn randv(rng: &mut Xoshiro256ss, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() - 0.5).collect()
}

/// The SIMD dispatch level is process-global; tests that pin it must not
/// interleave.  Every `configure`-calling test takes this lock.
static DISPATCH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// `PW2V_SIMD=scalar` (the CI dispatch-matrix leg) restricts the
/// configure-driven tests to the portable kernels, so the whole suite is
/// exercised once per dispatch level.
fn scalar_only() -> bool {
    std::env::var("PW2V_SIMD").map(|v| v == "scalar").unwrap_or(false)
}

/// GEMM kernels agree with the naive triple loop on random shapes.
#[test]
fn prop_gemm_matches_naive() {
    let mut rng = Xoshiro256ss::new(0xA11CE);
    for case in 0..200 {
        let m = 1 + rng.below(24);
        let n = 1 + rng.below(24);
        let k = 1 + rng.below(310);
        let a = randv(&mut rng, m * k);
        let b_nt = randv(&mut rng, n * k);
        let b_nn = randv(&mut rng, k * n);
        let a_tn = randv(&mut rng, k * m);

        let mut c = vec![0.0f32; m * n];
        gemm_nt(m, n, k, 1.0, &a, &b_nt, 0.0, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: f32 =
                    (0..k).map(|l| a[i * k + l] * b_nt[j * k + l]).sum();
                assert!(
                    (c[i * n + j] - want).abs() < 1e-3,
                    "case {case} nt ({m},{n},{k}) at ({i},{j})"
                );
            }
        }

        let mut c = vec![0.0f32; m * n];
        gemm_nn(m, n, k, 1.0, &a, &b_nn, 0.0, &mut c);
        // spot-check a random cell (full check is O(mnk) × 200 cases)
        let (i, j) = (rng.below(m), rng.below(n));
        let want: f32 = (0..k).map(|l| a[i * k + l] * b_nn[l * n + j]).sum();
        assert!((c[i * n + j] - want).abs() < 1e-3, "case {case} nn");

        let mut c = vec![0.0f32; m * n];
        gemm_tn(m, n, k, 1.0, &a_tn, &b_nn, 0.0, &mut c);
        let (i, j) = (rng.below(m), rng.below(n));
        let want: f32 = (0..k).map(|l| a_tn[l * m + i] * b_nn[l * n + j]).sum();
        assert!((c[i * n + j] - want).abs() < 1e-3, "case {case} tn");
    }
}

/// The AVX2 dispatch kernels agree with the scalar dispatch kernels
/// within 1e-4 across awkward shapes (lengths 1, 7, 8, 9, 300) and
/// UNALIGNED slice starts (offsets 1..4 f32s off any 32-byte boundary) —
/// gathered model blocks give no alignment guarantee, so the unaligned
/// path is the production path.
///
/// Tests that pin the process-global dispatch level serialise on
/// [`DISPATCH_LOCK`].
#[test]
fn prop_simd_matches_scalar_on_awkward_shapes() {
    // Pinning the process-global dispatch level must not interleave with
    // the fused-parity test below.
    let _guard = DISPATCH_LOCK.lock().unwrap();
    // First: `--simd scalar` must reproduce the portable kernels BIT FOR
    // BIT through the dispatcher.
    {
        let mut rng = Xoshiro256ss::new(0xB17);
        simd::configure(SimdMode::Scalar).unwrap();
        let a = randv(&mut rng, 300);
        let b = randv(&mut rng, 300);
        assert_eq!(simd::dot(&a, &b).to_bits(), dot(&a, &b).to_bits());
        let c0 = randv(&mut rng, 16 * 6);
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        let (wi, wo) = (randv(&mut rng, 16 * 300), randv(&mut rng, 6 * 300));
        simd::gemm_nt(16, 6, 300, 1.0, &wi, &wo, 0.5, &mut c1);
        gemm_nt(16, 6, 300, 1.0, &wi, &wo, 0.5, &mut c2);
        assert_eq!(
            c1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            c2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    if scalar_only() {
        simd::configure(SimdMode::Auto).unwrap();
        eprintln!("PW2V_SIMD=scalar: scalar dispatch verified, avx2 legs skipped");
        return;
    }
    if simd::configure(SimdMode::Avx2).is_err() {
        simd::configure(SimdMode::Auto).unwrap();
        eprintln!("skipping: this CPU has no avx2+fma");
        return;
    }
    let close = |x: f32, y: f32, what: &str| {
        assert!(
            (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
            "{what}: avx2 {x} vs scalar {y}"
        );
    };
    let mut rng = Xoshiro256ss::new(0x51D);

    // Level-1 kernels over lengths around the 8-lane width, with offsets.
    for &n in &[1usize, 7, 8, 9, 15, 16, 17, 300] {
        for off in 0..4usize {
            let abuf = randv(&mut rng, n + off);
            let bbuf = randv(&mut rng, n + off);
            let ybuf = randv(&mut rng, n + off);
            let (a, b) = (&abuf[off..], &bbuf[off..]);

            simd::configure(SimdMode::Scalar).unwrap();
            let want_dot = simd::dot(a, b);
            let mut want_y = ybuf[off..].to_vec();
            simd::axpy(0.37, a, &mut want_y);

            simd::configure(SimdMode::Avx2).unwrap();
            let got_dot = simd::dot(a, b);
            let mut got_y = ybuf[off..].to_vec();
            simd::axpy(0.37, a, &mut got_y);

            close(got_dot, want_dot, &format!("dot n={n} off={off}"));
            for i in 0..n {
                close(got_y[i], want_y[i], &format!("axpy n={n} off={off} i={i}"));
            }
        }
    }

    // GEMM kernels at the paper's shapes plus remainder-heavy ones.
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (2, 3, 5),
        (7, 9, 13),
        (16, 6, 7),
        (16, 6, 300),
        (6, 16, 300),
        (1, 6, 300),
        (5, 8, 9),
        (3, 11, 17),
    ];
    for &(m, n, k) in shapes {
        for off in 0..2usize {
            let abuf = randv(&mut rng, m * k + off);
            let bnt = randv(&mut rng, n * k + off);
            let bnn = randv(&mut rng, k * n + off);
            let atn = randv(&mut rng, k * m + off);
            let c0 = randv(&mut rng, m * n);
            let (alpha, beta) = (1.25f32, 0.5f32);

            simd::configure(SimdMode::Scalar).unwrap();
            let mut want_nt = c0.clone();
            gemm_via_dispatch_nt(m, n, k, alpha, &abuf[off..], &bnt[off..], beta, &mut want_nt);
            let mut want_nn = c0.clone();
            gemm_via_dispatch_nn(m, n, k, alpha, &abuf[off..], &bnn[off..], beta, &mut want_nn);
            let mut want_tn = c0.clone();
            gemm_via_dispatch_tn(m, n, k, alpha, &atn[off..], &bnn[off..], beta, &mut want_tn);

            simd::configure(SimdMode::Avx2).unwrap();
            let mut got_nt = c0.clone();
            gemm_via_dispatch_nt(m, n, k, alpha, &abuf[off..], &bnt[off..], beta, &mut got_nt);
            let mut got_nn = c0.clone();
            gemm_via_dispatch_nn(m, n, k, alpha, &abuf[off..], &bnn[off..], beta, &mut got_nn);
            let mut got_tn = c0.clone();
            gemm_via_dispatch_tn(m, n, k, alpha, &atn[off..], &bnn[off..], beta, &mut got_tn);

            for i in 0..m * n {
                close(got_nt[i], want_nt[i], &format!("nt ({m},{n},{k}) off={off} i={i}"));
                close(got_nn[i], want_nn[i], &format!("nn ({m},{n},{k}) off={off} i={i}"));
                close(got_tn[i], want_tn[i], &format!("tn ({m},{n},{k}) off={off} i={i}"));
            }
        }
    }

    // Fused error kernel: remainder lanes + positive-column fixup.
    for &(b, s) in &[(1usize, 2usize), (3, 5), (16, 6), (7, 9)] {
        let logits = randv(&mut rng, b * s);
        simd::configure(SimdMode::Scalar).unwrap();
        let mut want = logits.clone();
        simd::sgns_err(&mut want, s, 0.025);
        simd::configure(SimdMode::Avx2).unwrap();
        let mut got = logits.clone();
        simd::sgns_err(&mut got, s, 0.025);
        for i in 0..b * s {
            close(got[i], want[i], &format!("sgns_err b={b} s={s} i={i}"));
        }
    }

    simd::configure(SimdMode::Auto).unwrap();
}

/// The fused single-pass kernel matches the gemm3 chain
/// (`gemm_nt → sgns_err → gemm_nn → gemm_tn` + slot accumulation) within
/// 1e-4 across the awkward-shape matrix — B=1, odd S, D not a multiple of
/// 8, UNALIGNED slice starts, shuffled slot indirection, and duplicated
/// slots (two identical negative draws in one window, the kernel's
/// sequential-fallback path) — under every dispatch level this CPU has.
#[test]
fn prop_fused_matches_gemm3_chain() {
    let _guard = DISPATCH_LOCK.lock().unwrap();
    let mut modes = vec![SimdMode::Scalar];
    if !scalar_only() && simd::configure(SimdMode::Avx2).is_ok() {
        modes.push(SimdMode::Avx2);
    }
    let close = |x: f32, y: f32, what: &str| {
        assert!(
            (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
            "{what}: fused {x} vs gemm3 {y}"
        );
    };
    // (b, s, d): paper shape, B=1, odd S, D % 8 != 0, tiny everything.
    let shapes: &[(usize, usize, usize)] = &[
        (16, 6, 300),
        (1, 6, 300),
        (1, 2, 8),
        (3, 5, 7),
        (16, 6, 299),
        (16, 5, 301),
        (7, 3, 64),
        (4, 9, 17),
        (5, 6, 31),
        (2, 7, 1),
    ];
    let mut rng = Xoshiro256ss::new(0xF0CE);
    for &mode in &modes {
        simd::configure(mode).unwrap();
        for &(b, s, d) in shapes {
            let u = s + 3; // dedup block larger than the window's slot set
            for off in 0..2usize {
                for dup in [false, true] {
                    // Shuffled slot indirection; optionally force a
                    // duplicate (legal: repeated negative draw).
                    let mut slots: Vec<u32> = (0..u as u32).collect();
                    rng.shuffle(&mut slots);
                    let mut slots = slots[..s].to_vec();
                    if dup && s >= 2 {
                        // `s / 2` self-assigns when s == 2, so fall back
                        // to duplicating slot 0 — a real duplicate in
                        // every case.
                        let src = if s / 2 == s - 1 { 0 } else { s / 2 };
                        slots[s - 1] = slots[src];
                    }
                    let wibuf = randv(&mut rng, b * d + off);
                    let wobuf = randv(&mut rng, u * d + off);
                    let wi = &wibuf[off..];
                    let wo = &wobuf[off..];
                    let lr = 0.025f32;

                    // gemm3 chain, exactly as the arena path runs it.
                    let mut wo_blk = vec![0.0f32; s * d];
                    for (j, &slot) in slots.iter().enumerate() {
                        let r = slot as usize * d;
                        wo_blk[j * d..(j + 1) * d]
                            .copy_from_slice(&wo[r..r + d]);
                    }
                    let mut logits = vec![0.0f32; b * s];
                    simd::gemm_nt(b, s, d, 1.0, wi, &wo_blk, 0.0, &mut logits);
                    simd::sgns_err(&mut logits, s, lr);
                    let mut want_dwi = vec![0.0f32; b * d];
                    simd::gemm_nn(
                        b, d, s, 1.0, &logits, &wo_blk, 0.0, &mut want_dwi,
                    );
                    let mut dwo_blk = vec![0.0f32; s * d];
                    simd::gemm_tn(s, d, b, 1.0, &logits, wi, 0.0, &mut dwo_blk);
                    let mut want_dwo = vec![0.0f32; u * d];
                    for (j, &slot) in slots.iter().enumerate() {
                        let r = slot as usize * d;
                        simd::axpy(
                            1.0,
                            &dwo_blk[j * d..(j + 1) * d],
                            &mut want_dwo[r..r + d],
                        );
                    }

                    // Fused single call (err scratch deliberately dirty).
                    let mut err = randv(&mut rng, b * s);
                    let mut got_dwi = randv(&mut rng, b * d);
                    let mut got_dwo = vec![0.0f32; u * d];
                    simd::sgns_fused(
                        s,
                        d,
                        lr,
                        wi,
                        wo,
                        &slots,
                        &mut err,
                        &mut got_dwi,
                        &mut got_dwo,
                    );

                    let what =
                        format!("({b},{s},{d}) off={off} dup={dup} {mode:?}");
                    for i in 0..b * d {
                        close(got_dwi[i], want_dwi[i], &format!("dwi {what} i={i}"));
                    }
                    for i in 0..u * d {
                        close(got_dwo[i], want_dwo[i], &format!("dwo {what} i={i}"));
                    }
                }
            }
        }
    }
    simd::configure(SimdMode::Auto).unwrap();
}

#[allow(clippy::too_many_arguments)]
fn gemm_via_dispatch_nt(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    simd::gemm_nt(m, n, k, alpha, &a[..m * k], &b[..n * k], beta, c);
}

#[allow(clippy::too_many_arguments)]
fn gemm_via_dispatch_nn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    simd::gemm_nn(m, n, k, alpha, &a[..m * k], &b[..k * n], beta, c);
}

#[allow(clippy::too_many_arguments)]
fn gemm_via_dispatch_tn(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    simd::gemm_tn(m, n, k, alpha, &a[..k * m], &b[..k * n], beta, c);
}

/// Shards partition any length exactly, for any shard/thread counts.
#[test]
fn prop_shards_partition() {
    let mut rng = Xoshiro256ss::new(0x5AAD);
    for _ in 0..300 {
        let len = rng.below(10_000_000) as u64;
        let n = 1 + rng.below(64);
        let shards = shards_for_len(len, n);
        assert_eq!(shards.len(), n);
        let mut cursor = 0u64;
        for s in &shards {
            assert_eq!(s.start, cursor);
            cursor = s.end;
        }
        assert_eq!(cursor, len);
        // Nested subshards partition their parent.
        let t = 1 + rng.below(8);
        for s in &shards {
            let subs = subshards(*s, t);
            let mut c = s.start;
            for sub in &subs {
                assert_eq!(sub.start, c);
                c = sub.end;
            }
            assert_eq!(c, s.end);
        }
    }
}

/// Spearman is invariant under strictly monotone transforms and bounded
/// in [-1, 1].
#[test]
fn prop_spearman_monotone_invariance() {
    let mut rng = Xoshiro256ss::new(0x0E0);
    for _ in 0..200 {
        let n = 3 + rng.below(100);
        let a: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let Some(rho) = spearman(&a, &b) else { continue };
        assert!((-1.0..=1.0).contains(&rho));
        // Monotone transform of a leaves rho unchanged.
        let a2: Vec<f64> = a.iter().map(|x| (3.0 * x).exp() + 7.0).collect();
        let rho2 = spearman(&a2, &b).unwrap();
        assert!((rho - rho2).abs() < 1e-9, "{rho} vs {rho2}");
        // Symmetry.
        let rho3 = spearman(&b, &a).unwrap();
        assert!((rho - rho3).abs() < 1e-9);
    }
}

/// Training deltas of the GEMM backend always improve the window's own
/// objective for small lr (ascent property on random models/windows).
#[test]
fn prop_gemm_step_is_ascent() {
    let mut rng = Xoshiro256ss::new(0xBEEF);
    for case in 0..60 {
        let v = 20 + rng.below(50);
        let dim = 8 + rng.below(48);
        let model = SharedModel::init(v, dim, rng.next_u64());
        // Random prewarm so M_out is nonzero.
        for r in 0..v as u32 {
            // SAFETY: single-threaded test.
            let row = unsafe { model.row_out(r) };
            for x in row {
                *x = rng.next_f32() * 0.2 - 0.1;
            }
        }
        let b = 1 + rng.below(8);
        let s = 2 + rng.below(6);
        let mut ids: Vec<u32> = (0..v as u32).collect();
        rng.shuffle(&mut ids);
        let window = Window {
            inputs: ids[..b].to_vec(),
            outputs: ids[b..b + s].to_vec(),
        };
        let windows = vec![window];
        let before = pw2v::train::ns_objective(&model, &windows);
        let mut backend = GemmBackend::new(dim, 8, 8);
        backend.process(model.store(), &windows, 0.01).unwrap();
        let after = pw2v::train::ns_objective(&model, &windows);
        assert!(
            after > before - 1e-9,
            "case {case}: objective fell {before} -> {after}"
        );
    }
}

/// JSON parser round-trips random values produced by the writer.
#[test]
fn prop_json_roundtrip() {
    fn gen(rng: &mut Xoshiro256ss, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0).round()),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Xoshiro256ss::new(0x15E);
    for _ in 0..300 {
        let v = gen(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, v, "{text}");
    }
}

/// dot(a,b) is symmetric and linear in its first argument.
#[test]
fn prop_dot_linearity() {
    let mut rng = Xoshiro256ss::new(0xD07);
    for _ in 0..200 {
        let n = 1 + rng.below(512);
        let a = randv(&mut rng, n);
        let b = randv(&mut rng, n);
        let c = randv(&mut rng, n);
        let lhs = dot(&a, &b) + dot(&c, &b);
        let sum: Vec<f32> = a.iter().zip(&c).map(|(x, y)| x + y).collect();
        let rhs = dot(&sum, &b);
        assert!((lhs - rhs).abs() < 1e-2, "{lhs} vs {rhs} (n={n})");
        assert!((dot(&a, &b) - dot(&b, &a)).abs() < 1e-4);
    }
}

// ---------------------------------------------------------------------------
// PR 10: cross-window negative reuse (`sgns_fused_run`) and the AVX-512
// dispatch tier.  Everything below is new; the contracts above predate
// the reuse path and stay untouched.
// ---------------------------------------------------------------------------

use pw2v::config::{KernelMode, ReuseMode};
use pw2v::sampling::batch::SuperbatchArena;

/// Dispatch levels the reuse/AVX-512 matrix tests exercise this run.
/// `PW2V_SIMD=scalar` / `PW2V_SIMD=avx512` (the CI dispatch-matrix legs)
/// pin one vector tier next to the scalar reference; without the env var
/// every level this CPU supports is covered.  A pinned tier the CPU
/// lacks soft-skips with an explicit log line, so the avx512 CI leg
/// stays green on avx2-only runners.  Callers hold [`DISPATCH_LOCK`].
fn matrix_modes() -> Vec<SimdMode> {
    let mut modes = vec![SimdMode::Scalar];
    match std::env::var("PW2V_SIMD").as_deref() {
        Ok("scalar") => {}
        Ok("avx512") => {
            if simd::configure(SimdMode::Avx512).is_ok() {
                modes.push(SimdMode::Avx512);
            } else {
                eprintln!(
                    "PW2V_SIMD=avx512: this CPU lacks avx512f+avx512bw, \
                     avx512 legs soft-skipped"
                );
            }
        }
        _ => {
            if simd::configure(SimdMode::Avx2).is_ok() {
                modes.push(SimdMode::Avx2);
            } else {
                eprintln!("skipping avx2 legs: this CPU has no avx2+fma");
            }
            if simd::configure(SimdMode::Avx512).is_ok() {
                modes.push(SimdMode::Avx512);
            } else {
                eprintln!(
                    "skipping avx512 legs: this CPU has no avx512f+avx512bw"
                );
            }
        }
    }
    simd::configure(SimdMode::Auto).unwrap();
    modes
}

/// `sgns_fused_run` is BIT-FOR-BIT `R` consecutive `sgns_fused` calls at
/// the same dispatch level — the reuse tentpole's correctness contract
/// (mod docs point here) — across awkward geometry: R=1 singleton runs
/// (the driver's duplicate-slot route), per-window row counts down to
/// B=1, D % 16 != 0 (both vector tiers' remainder lanes), and D smaller
/// than one vector register.
#[test]
fn prop_fused_run_bitwise_equals_sequential_fused() {
    let _guard = DISPATCH_LOCK.lock().unwrap();
    let mut rng = Xoshiro256ss::new(0xF0CE2);
    // (r_n, s, d): windows per run × samples × dim.
    let shapes: &[(usize, usize, usize)] = &[
        (1, 6, 300), // singleton run (the dup-slot fallback route)
        (2, 2, 1),   // everything minimal
        (3, 6, 300), // paper shape
        (4, 6, 299), // D % 16 != 0 (avx512 remainder), % 8 != 0 (avx2)
        (5, 5, 17),
        (8, 3, 7),   // D below one 8-lane register
        (8, 6, 304), // D % 16 == 0 but not a multiple of 32
        (2, 9, 33),
    ];
    for mode in matrix_modes() {
        simd::configure(mode).unwrap();
        for &(r_n, s, d) in shapes {
            let u = r_n + s + 2;
            // Driver contract for multi-window runs: negatives shared,
            // positives distinct, every window dup-free.
            let negs: Vec<u32> =
                (r_n as u32..(r_n + s - 1) as u32).collect();
            let mut slots = Vec::with_capacity(r_n * s);
            for w in 0..r_n as u32 {
                slots.push(w);
                slots.extend_from_slice(&negs);
            }
            // CSR row offsets with varying window widths, B=1 included.
            let mut offs = vec![0u32];
            for w in 0..r_n {
                let b = 1 + (w + rng.below(3)) % 4;
                offs.push(offs[w] + b as u32);
            }
            let rows = *offs.last().unwrap() as usize;
            let wi = randv(&mut rng, rows * d);
            let wo = randv(&mut rng, u * d);
            let lr = 0.025f32;

            // Reference: R consecutive sgns_fused calls — the run
            // kernel's DEFINED semantics — at the same level.
            let mut want_err = vec![0.0f32; rows * s];
            let mut want_dwi = vec![0.0f32; rows * d];
            let mut want_dwo = vec![0.0f32; u * d];
            for w in 0..r_n {
                let (lo, hi) = (offs[w] as usize, offs[w + 1] as usize);
                simd::sgns_fused(
                    s,
                    d,
                    lr,
                    &wi[lo * d..hi * d],
                    &wo,
                    &slots[w * s..(w + 1) * s],
                    &mut want_err[lo * s..hi * s],
                    &mut want_dwi[lo * d..hi * d],
                    &mut want_dwo,
                );
            }

            let mut got_err = vec![0.0f32; rows * s];
            let mut got_dwi = vec![0.0f32; rows * d];
            let mut got_dwo = vec![0.0f32; u * d];
            simd::sgns_fused_run(
                s, d, lr, &wi, &offs, &wo, &slots, &mut got_err,
                &mut got_dwi, &mut got_dwo,
            );

            let what = format!("({r_n},{s},{d}) {mode:?}");
            for i in 0..rows * d {
                assert_eq!(
                    got_dwi[i].to_bits(),
                    want_dwi[i].to_bits(),
                    "dwi {what} i={i}: {} vs {}",
                    got_dwi[i],
                    want_dwi[i]
                );
            }
            for i in 0..u * d {
                assert_eq!(
                    got_dwo[i].to_bits(),
                    want_dwo[i].to_bits(),
                    "dwo {what} i={i}: {} vs {}",
                    got_dwo[i],
                    want_dwo[i]
                );
            }
        }
    }
    simd::configure(SimdMode::Auto).unwrap();
}

/// The trainer-surface matrix the reuse path ships under:
/// {scalar, avx2, avx512} × {fused, gemm3} × {off, window, sentence} on
/// one thread.  `--reuse window` must be BIT-FOR-BIT `--reuse off`
/// (singleton runs process identical slices through identical kernels);
/// `--reuse sentence` is bitwise-equal here too because every window's
/// inputs are distinct, so the run driver's deferred input scatter is
/// unobservable.  Geometry is deliberately awkward: D % 8 != 0, a
/// singleton-window sentence, a B=1 window, and a window that repeats
/// its own positive as a negative (duplicate slot — routed to a
/// singleton run where the kernels' sequential fallback applies).
#[test]
fn prop_reuse_matrix_levels_kernels() {
    let _guard = DISPATCH_LOCK.lock().unwrap();
    const D: usize = 17;
    const V: usize = 70;
    let s = 6;
    let lr = 0.025f32;

    let arena = {
        let mut a = SuperbatchArena::new(4, s);
        let negs_a = [40u32, 41, 42, 43, 44];
        let negs_b = [50u32, 51, 52, 53, 54];
        let negs_c = [60u32, 61, 62, 63, 64];
        // Sentence 0: three windows sharing one negative set (a run).
        for (target, inputs) in [
            (10u32, &[1u32, 2, 3][..]),
            (11, &[4][..]),
            (12, &[5, 6, 7, 8][..]),
        ] {
            let mut outs = vec![target];
            outs.extend_from_slice(&negs_a);
            a.push_window_in_sentence(inputs, &outs, 0);
        }
        // Sentence 1: a singleton window (run of length one).
        let mut outs = vec![13u32];
        outs.extend_from_slice(&negs_b);
        a.push_window_in_sentence(&[9], &outs, 1);
        // Sentence 2: clean window, then a duplicate-slot window (its
        // positive repeated as the last negative).
        let mut outs = vec![14u32];
        outs.extend_from_slice(&negs_c);
        a.push_window_in_sentence(&[16, 17], &outs, 2);
        a.push_window_in_sentence(&[18], &[15, 60, 61, 62, 63, 15], 2);
        a
    };

    // Deterministic nonzero M_out so every gradient path is live.
    let prewarmed = |seed: u64| {
        let model = SharedModel::init(V, D, seed);
        for r in 0..V as u32 {
            // SAFETY: single-threaded test.
            let row = unsafe { model.row_out(r) };
            for (i, x) in row.iter_mut().enumerate() {
                *x = 0.01 * ((r as usize * 31 + i) % 17) as f32 - 0.08;
            }
        }
        model
    };
    let run = |kernel: KernelMode, reuse: ReuseMode| {
        let model = prewarmed(99);
        let mut backend = GemmBackend::new(D, 4, s)
            .with_kernel(kernel)
            .with_reuse(reuse);
        backend.process_arena(model.store(), &arena, lr).unwrap();
        model
    };
    let bits = |m: &SharedModel| {
        let mut v: Vec<u32> =
            m.m_in().data().iter().map(|x| x.to_bits()).collect();
        v.extend(m.m_out().data().iter().map(|x| x.to_bits()));
        v
    };

    for mode in matrix_modes() {
        simd::configure(mode).unwrap();
        for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
            let off = bits(&run(kernel, ReuseMode::Off));
            // The model must actually move, or the equalities are vacuous.
            let init = bits(&prewarmed(99));
            assert_ne!(off, init, "{mode:?}/{kernel}: model did not move");
            let window = bits(&run(kernel, ReuseMode::Window));
            assert_eq!(
                off, window,
                "{mode:?}/{kernel}: --reuse window drifted from off"
            );
            let sentence = bits(&run(kernel, ReuseMode::Sentence));
            assert_eq!(
                off, sentence,
                "{mode:?}/{kernel}: --reuse sentence drifted from off \
                 on distinct-input windows"
            );
        }
    }
    simd::configure(SimdMode::Auto).unwrap();
}
