//! Shared helpers for the parity integration suites (`backend_parity`,
//! `numa_parity`).  Lives under `tests/common/` so cargo does not build
//! it as its own test binary.

use pw2v::SharedModel;

/// Max |a − b| over both embedding matrices, plus max |a − init| — the
/// drift-vs-movement machinery both parity suites bound racy/arena
/// divergence with: an equivalence assertion is only meaningful as
/// "models agree AND they moved".
pub fn model_gap(
    a: &SharedModel,
    b: &SharedModel,
    vocab: usize,
    dim: usize,
    seed: u64,
) -> (f64, f64) {
    assert_eq!(a.vocab(), vocab);
    assert_eq!(b.vocab(), vocab);
    let init = SharedModel::init(vocab, dim, seed);
    let mut gap = 0.0f64;
    let mut moved = 0.0f64;
    for r in 0..vocab as u32 {
        for ((x, y), z) in a
            .m_in()
            .row(r)
            .iter()
            .zip(b.m_in().row(r))
            .zip(init.m_in().row(r))
        {
            gap = gap.max((x - y).abs() as f64);
            moved = moved.max((x - z).abs() as f64);
        }
        for ((x, y), z) in a
            .m_out()
            .row(r)
            .iter()
            .zip(b.m_out().row(r))
            .zip(init.m_out().row(r))
        {
            gap = gap.max((x - y).abs() as f64);
            moved = moved.max((x - z).abs() as f64);
        }
    }
    (gap, moved)
}
