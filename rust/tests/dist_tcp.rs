//! Multi-PROCESS TCP-ring integration: real OS processes of the release
//! test binary forming a loopback ring through the CLI, pinned against
//! the acceptance criteria:
//!
//! * a 3-process full-sync ring writes BITWISE-identical vectors to the
//!   3-replica thread-mode driver (every rank ends with the merged
//!   model);
//! * an interrupted checkpointed run (rank killed mid-epoch by
//!   `PW2V_FAULT`) leaves loadable checkpoints, the survivor exits
//!   non-zero within the i/o deadline, and `--resume` completes and
//!   passes the embedding-quality floors of `quality_regression`.
//!
//! In-process ring parity (including checkpoint/resume bitwise equality)
//! lives in `src/dist/train.rs` tests; THIS suite is the only place the
//! transport crosses a real process boundary.  Subprocess scenarios are
//! serialized by a file-local mutex so rings never fight for CPUs.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Child, Command};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::eval;
use pw2v::model::io as model_io;

static SERIAL: Mutex<()> = Mutex::new(());

/// Quality floors, matching `tests/quality_regression.rs`.
const RHO_FLOOR: f64 = 15.0;
const ANALOGY_FLOOR: f64 = 0.5;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_pw2v")
}

/// Reserve n distinct loopback ports.  Binding `:0` and dropping leaves
/// a tiny race before the ranks re-bind; fine on a CI loopback.
fn free_ports(n: usize) -> Vec<u16> {
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    listeners
        .iter()
        .map(|l| l.local_addr().unwrap().port())
        .collect()
}

fn ring_addrs(ports: &[u16]) -> String {
    ports
        .iter()
        .map(|p| format!("127.0.0.1:{p}"))
        .collect::<Vec<_>>()
        .join(",")
}

/// Wait for a child with a deadline; kill and panic on expiry so a
/// wedged ring fails the test instead of hanging the suite.
fn wait_deadline(mut child: Child, what: &str, deadline: Duration) -> std::process::ExitStatus {
    let t0 = Instant::now();
    loop {
        if let Some(st) = child.try_wait().unwrap() {
            return st;
        }
        if t0.elapsed() > deadline {
            child.kill().ok();
            child.wait().ok();
            panic!("{what} still running after {deadline:?}");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

struct Fixture {
    dir: PathBuf,
    corpus: PathBuf,
    latent: LatentModel,
}

impl Drop for Fixture {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn fixture(name: &str, scfg: SyntheticConfig) -> Fixture {
    let dir = std::env::temp_dir().join(format!(
        "pw2v_dist_tcp_{name}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let latent = LatentModel::new(scfg);
    let corpus = dir.join("corpus.txt");
    latent.write_corpus(&corpus).unwrap();
    Fixture {
        dir,
        corpus,
        latent,
    }
}

/// Common `train-dist` argv for one rank of a ring.
#[allow(clippy::too_many_arguments)]
fn rank_cmd(
    corpus: &Path,
    rank: usize,
    addrs: &str,
    out: Option<&Path>,
    extra: &[&str],
) -> Command {
    let mut c = Command::new(bin());
    c.args([
        "train-dist",
        "--corpus",
        corpus.to_str().unwrap(),
        "--dist",
        &format!("tcp:{rank}@{addrs}"),
        "--min-count",
        "1",
    ]);
    if let Some(o) = out {
        c.args(["--out", o.to_str().unwrap()]);
    }
    c.args(extra);
    c
}

/// THE acceptance criterion, across real process boundaries: a
/// 3-process loopback ring under full sync writes the same vectors,
/// byte for byte, as `--dist threads --nodes 3`.
#[test]
fn three_process_full_sync_ring_matches_thread_mode() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let mut scfg = SyntheticConfig::test_tiny();
    scfg.tokens = 40_000;
    scfg.seed = 101;
    let f = fixture("parity", scfg);
    let common = [
        "--policy",
        "full",
        "--sync-interval",
        "4000",
        "--dim",
        "32",
        "--epochs",
        "1",
        "--sample",
        "0",
    ];

    // Reference: the in-process replica-thread driver.
    let threads_out = f.dir.join("threads.txt");
    let st = Command::new(bin())
        .args([
            "train-dist",
            "--corpus",
            f.corpus.to_str().unwrap(),
            "--nodes",
            "3",
            "--min-count",
            "1",
            "--out",
            threads_out.to_str().unwrap(),
        ])
        .args(common)
        .status()
        .unwrap();
    assert!(st.success(), "thread-mode reference run failed");

    // The ring: one OS process per rank.
    let addrs = ring_addrs(&free_ports(3));
    let outs: Vec<PathBuf> = (0..3).map(|r| f.dir.join(format!("rank{r}.txt"))).collect();
    let children: Vec<Child> = (0..3)
        .map(|r| {
            rank_cmd(&f.corpus, r, &addrs, Some(&outs[r]), &common)
                .spawn()
                .unwrap()
        })
        .collect();
    for (r, ch) in children.into_iter().enumerate() {
        let st = wait_deadline(ch, &format!("rank {r}"), Duration::from_secs(120));
        assert!(st.success(), "rank {r} exited with {st}");
    }

    let reference = std::fs::read(&threads_out).unwrap();
    assert!(!reference.is_empty());
    for (r, out) in outs.iter().enumerate() {
        let got = std::fs::read(out).unwrap();
        assert_eq!(
            got, reference,
            "rank {r} vectors differ from thread mode (parity broken)"
        );
    }
}

/// Kill → survivors fail fast → checkpoints survive → `--resume`
/// completes → the resumed embeddings still clear the quality floors.
/// The whole fault-tolerance story end to end through the CLI.
#[test]
fn resume_after_mid_epoch_kill_passes_quality_floors() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // The `quality_regression` fixture geometry.
    let scfg = SyntheticConfig {
        vocab: 2_000,
        tokens: 300_000,
        clusters: 20,
        beta: 5.0,
        seed: 29,
        ..SyntheticConfig::default()
    };
    let f = fixture("resume", scfg);
    let ck_base = f.dir.join("ck");
    let ck = ck_base.to_str().unwrap().to_string();
    let common = [
        "--sync-interval",
        "20000",
        "--dim",
        "48",
        "--epochs",
        "3",
        "--checkpoint-every",
        "1",
        "--net-timeout-ms",
        "5000",
        "--heartbeat-ms",
        "100",
    ];

    // Leg 1: rank 1 is killed by fault injection after 120 data frames
    // (mid-epoch: each sub-model round is ~10 frames and one epoch is
    // ~7 rounds per rank here).  The survivor must exit non-zero within
    // its i/o deadline — not hang.
    let addrs = ring_addrs(&free_ports(2));
    let t0 = Instant::now();
    let surv = rank_cmd(&f.corpus, 0, &addrs, None, &common)
        .args(["--checkpoint", &ck])
        .spawn()
        .unwrap();
    let victim = rank_cmd(&f.corpus, 1, &addrs, None, &common)
        .args(["--checkpoint", &ck])
        .env("PW2V_FAULT", "kill-after=120")
        .spawn()
        .unwrap();
    let st_victim = wait_deadline(victim, "killed rank", Duration::from_secs(60));
    assert_eq!(
        st_victim.code(),
        Some(42),
        "injected kill must exit with the kill code"
    );
    let st_surv = wait_deadline(surv, "survivor rank", Duration::from_secs(60));
    assert!(
        !st_surv.success(),
        "survivor must fail once its peer is gone"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "failure propagation took {:?}",
        t0.elapsed()
    );

    // Both ranks left loadable checkpoints, skewed at most one round.
    let rounds: Vec<u64> = (0..2)
        .map(|r| {
            model_io::latest_checkpoint(&ck_base, r)
                .unwrap_or_else(|| panic!("rank {r} left no loadable checkpoint"))
                .round
        })
        .collect();
    assert!(rounds[0] >= 1 && rounds[1] >= 1, "rounds {rounds:?}");
    assert!(rounds[0].abs_diff(rounds[1]) <= 1, "rounds {rounds:?}");

    // Leg 2: resume from the negotiated common round and run to
    // completion.
    let addrs = ring_addrs(&free_ports(2));
    let vec_out = f.dir.join("resumed.txt");
    let children: Vec<Child> = (0..2)
        .map(|r| {
            rank_cmd(
                &f.corpus,
                r,
                &addrs,
                (r == 0).then_some(vec_out.as_path()),
                &common,
            )
            .args(["--checkpoint", &ck, "--resume"])
            .spawn()
            .unwrap()
        })
        .collect();
    for (r, ch) in children.into_iter().enumerate() {
        let st = wait_deadline(ch, &format!("resumed rank {r}"), Duration::from_secs(300));
        assert!(st.success(), "resumed rank {r} exited with {st}");
    }

    // The resumed model must have LEARNED: same floors as
    // `quality_regression` (chance: rho ~0, analogy ~0.05%).
    let vocab = Vocab::build_from_file(&f.corpus, 1).unwrap();
    let (words, emb) = model_io::load_text(&vec_out).unwrap();
    assert_eq!(words.len(), vocab.len());
    let sim_set = eval::gen_similarity_set(&f.latent, 200, 3);
    let ana_set = eval::gen_analogy_set(&f.latent);
    let rho = eval::eval_similarity(&sim_set, &vocab, &emb).rho100;
    let ana = eval::eval_analogy(&ana_set, &vocab, &emb).accuracy100();
    assert!(
        rho > RHO_FLOOR,
        "resumed run stopped learning: rho100 {rho:.1} <= {RHO_FLOOR}"
    );
    assert!(
        ana > ANALOGY_FLOOR,
        "resumed run stopped learning: analogy {ana:.2}% <= {ANALOGY_FLOOR}%"
    );
}

/// `--resume` without any checkpoints on disk must refuse cleanly (every
/// rank, non-zero, helpful message) rather than train from scratch.
#[test]
fn resume_without_checkpoints_refuses() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let mut scfg = SyntheticConfig::test_tiny();
    scfg.tokens = 20_000;
    scfg.seed = 103;
    let f = fixture("noresume", scfg);
    let ck = f.dir.join("missing").to_str().unwrap().to_string();
    let addrs = ring_addrs(&free_ports(2));
    let children: Vec<Child> = (0..2)
        .map(|r| {
            rank_cmd(
                &f.corpus,
                r,
                &addrs,
                None,
                &["--dim", "16", "--epochs", "1", "--sync-interval", "4000"],
            )
            .args(["--checkpoint", &ck, "--resume"])
            .stderr(std::process::Stdio::piped())
            .spawn()
            .unwrap()
        })
        .collect();
    for (r, ch) in children.into_iter().enumerate() {
        let out = ch.wait_with_output().unwrap();
        assert!(!out.status.success(), "rank {r} must refuse to resume");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            err.contains("no loadable checkpoint"),
            "rank {r} stderr: {err}"
        );
    }
}
