//! Deterministic fault-injection suite (`PW2V_FAULT`) against the
//! multi-process TCP ring: every failure mode the transport claims to
//! survive, exercised through real OS processes of the CLI binary.
//!
//! * `kill-after=N` — a rank exits hard (code 42) after N data frames:
//!   the survivor must exit non-zero within its i/o deadline and both
//!   ranks' checkpoints must remain loadable (crash consistency);
//! * `torn-frame=N` — a rank dies mid-frame (code 43), leaving a
//!   half-written frame on the wire: the receiver must reject the
//!   truncation, never parse garbage;
//! * `stall-after=N` — a rank wedges (alive, silent, heartbeats
//!   stopped): the peer's heartbeat deadline must fire;
//! * `panic-replica=I` — THREAD-mode: a panicking replica poisons the
//!   shared barrier and the whole process fails fast instead of
//!   deadlocking (the pre-PR hang this suite regression-pins);
//! * **elastic recovery** (`--on-failure shrink|rejoin`): a killed rank
//!   triggers regroup + rollback instead of an abort — the healed run
//!   must be bitwise-equal to a clean run launched from the same
//!   rollback state, and a promptly respawned rank must be re-admitted
//!   within the rejoin grace window.
//!
//! Scenarios are serialized by a file-local mutex.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::dist::{
    average_row, train_tcp_ring_from, AttemptStart, CheckpointPolicy, DistConfig, DistOutcome,
    NetConfig, RingSpec,
};
use pw2v::model::io as model_io;
use pw2v::SharedModel;

static SERIAL: Mutex<()> = Mutex::new(());

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_pw2v")
}

fn free_ports(n: usize) -> Vec<u16> {
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    listeners
        .iter()
        .map(|l| l.local_addr().unwrap().port())
        .collect()
}

fn ring_addrs(ports: &[u16]) -> String {
    ports
        .iter()
        .map(|p| format!("127.0.0.1:{p}"))
        .collect::<Vec<_>>()
        .join(",")
}

struct Fixture {
    dir: PathBuf,
    corpus: PathBuf,
}

impl Drop for Fixture {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn fixture(name: &str) -> Fixture {
    let dir = std::env::temp_dir().join(format!(
        "pw2v_dist_fault_{name}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let mut scfg = SyntheticConfig::test_tiny();
    scfg.tokens = 60_000;
    scfg.seed = 113;
    let corpus = dir.join("corpus.txt");
    LatentModel::new(scfg).write_corpus(&corpus).unwrap();
    Fixture { dir, corpus }
}

/// One rank of a 2-rank ring on the fault fixture: small dim, many
/// rounds, tight failure-detection deadlines.
fn rank_cmd(corpus: &Path, rank: usize, addrs: &str) -> Command {
    let mut c = Command::new(bin());
    c.args([
        "train-dist",
        "--corpus",
        corpus.to_str().unwrap(),
        "--dist",
        &format!("tcp:{rank}@{addrs}"),
        "--min-count",
        "1",
        "--dim",
        "16",
        "--epochs",
        "2",
        "--sync-interval",
        "4000",
        "--net-timeout-ms",
        "4000",
        "--heartbeat-ms",
        "100",
    ]);
    c.stderr(Stdio::piped());
    c
}

fn wait_deadline(mut child: Child, what: &str, deadline: Duration) -> std::process::Output {
    let t0 = Instant::now();
    loop {
        if child.try_wait().unwrap().is_some() {
            return child.wait_with_output().unwrap();
        }
        if t0.elapsed() > deadline {
            child.kill().ok();
            child.wait().ok();
            panic!("{what} still running after {deadline:?}");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Kill one rank mid-run: the victim exits with the injected code, the
/// survivor exits non-zero within its deadline, and both ranks'
/// two-slot checkpoints are still loadable (atomic tmp+rename+fsync —
/// a crash can never leave a half-written "latest").
#[test]
fn killed_rank_fails_survivor_fast_and_checkpoints_stay_loadable() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("kill");
    let ck_base = f.dir.join("ck");
    let ck = ck_base.to_str().unwrap().to_string();
    let addrs = ring_addrs(&free_ports(2));
    let t0 = Instant::now();
    let surv = rank_cmd(&f.corpus, 0, &addrs)
        .args(["--checkpoint", &ck, "--checkpoint-every", "1"])
        .spawn()
        .unwrap();
    let victim = rank_cmd(&f.corpus, 1, &addrs)
        .args(["--checkpoint", &ck, "--checkpoint-every", "1"])
        .env("PW2V_FAULT", "kill-after=40")
        .spawn()
        .unwrap();

    let out_victim = wait_deadline(victim, "killed rank", Duration::from_secs(60));
    assert_eq!(out_victim.status.code(), Some(42));
    let out_surv = wait_deadline(surv, "survivor", Duration::from_secs(60));
    assert!(!out_surv.status.success(), "survivor must not succeed");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "survivor took {:?} to notice the dead peer",
        t0.elapsed()
    );
    let err = String::from_utf8_lossy(&out_surv.stderr);
    assert!(
        err.contains("error:"),
        "survivor exited silently: {err}"
    );

    for rank in 0..2 {
        let ck = model_io::latest_checkpoint(&ck_base, rank)
            .unwrap_or_else(|| panic!("rank {rank}: no loadable checkpoint after crash"));
        assert!(ck.round >= 1);
        assert_eq!(ck.m_in.dim(), 16);
    }
}

/// A torn frame (header promises more payload than ever arrives) must be
/// rejected as truncation by the receiving rank — never parsed as a
/// short-but-valid frame.
#[test]
fn torn_frame_is_rejected_not_parsed() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("torn");
    let addrs = ring_addrs(&free_ports(2));
    let surv = rank_cmd(&f.corpus, 0, &addrs).spawn().unwrap();
    let victim = rank_cmd(&f.corpus, 1, &addrs)
        .env("PW2V_FAULT", "torn-frame=10")
        .spawn()
        .unwrap();

    let out_victim = wait_deadline(victim, "torn rank", Duration::from_secs(60));
    assert_eq!(out_victim.status.code(), Some(43));
    let out_surv = wait_deadline(surv, "survivor", Duration::from_secs(60));
    assert!(!out_surv.status.success());
    let err = String::from_utf8_lossy(&out_surv.stderr);
    // Whichever the survivor hits first — the half frame (truncation) or
    // the dropped connection — it must be a transport diagnostic, not a
    // decode of garbage.
    assert!(
        err.contains("truncat") || err.contains("closed") || err.contains("silent"),
        "survivor error does not look like a transport failure: {err}"
    );
}

/// A stalled (wedged, not dead) peer stops heartbeating; the survivor's
/// read deadline must fire even though the TCP connection stays open.
#[test]
fn stalled_peer_trips_heartbeat_deadline() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("stall");
    let addrs = ring_addrs(&free_ports(2));
    let surv = rank_cmd(&f.corpus, 0, &addrs).spawn().unwrap();
    let stalled = rank_cmd(&f.corpus, 1, &addrs)
        .env("PW2V_FAULT", "stall-after=10")
        .spawn()
        .unwrap();

    let t0 = Instant::now();
    let out_surv = wait_deadline(surv, "survivor", Duration::from_secs(60));
    assert!(!out_surv.status.success());
    // Detection is deadline-based: must take at least roughly the i/o
    // timeout (nothing errored eagerly) and comfortably less than the
    // suite deadline.
    assert!(
        t0.elapsed() < Duration::from_secs(45),
        "deadline detection took {:?}",
        t0.elapsed()
    );
    let err = String::from_utf8_lossy(&out_surv.stderr);
    assert!(
        err.contains("silent") || err.contains("closed"),
        "expected a liveness diagnostic: {err}"
    );
    // The stalled process sleeps forever by design: reap it.
    let mut stalled = stalled;
    stalled.kill().ok();
    stalled.wait().ok();
}

/// Thread-mode fault wiring through the CLI: a panicking replica must
/// fail the whole process fast (poisoned barrier), not deadlock it.
#[test]
fn thread_mode_replica_panic_fails_process() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("panic");
    let child = Command::new(bin())
        .args([
            "train-dist",
            "--corpus",
            f.corpus.to_str().unwrap(),
            "--nodes",
            "2",
            "--min-count",
            "1",
            "--dim",
            "16",
            "--epochs",
            "1",
            "--sync-interval",
            "4000",
        ])
        .env("PW2V_FAULT", "panic-replica=1")
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let out = wait_deadline(child, "thread-mode run", Duration::from_secs(60));
    assert!(!out.status.success(), "panicking replica must fail the run");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("panic"), "stderr lacks the panic report: {err}");
}

/// Malformed `PW2V_FAULT` values are a startup error, not a silent
/// no-op — a typo'd fault spec in a harness must never "pass" by
/// accidentally running fault-free.
#[test]
fn malformed_fault_spec_is_refused_at_startup() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("badspec");
    let child = Command::new(bin())
        .args([
            "train-dist",
            "--corpus",
            f.corpus.to_str().unwrap(),
            "--nodes",
            "2",
            "--min-count",
            "1",
            "--dim",
            "16",
            "--epochs",
            "1",
        ])
        .env("PW2V_FAULT", "explode-eventually")
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let out = wait_deadline(child, "bad-spec run", Duration::from_secs(30));
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("PW2V_FAULT"), "stderr: {err}");
}

/// One rank of a 3-rank SELF-HEALING ring (`--on-failure`), with
/// per-round checkpoints and a per-rank `--out` vectors file.
fn heal_rank_cmd(
    f: &Fixture,
    rank: usize,
    addrs: &str,
    on_failure: &str,
    kernel: &str,
) -> Command {
    let ck = f.dir.join("ck");
    let out = f.dir.join(format!("vec{rank}.txt"));
    let mut c = rank_cmd(&f.corpus, rank, addrs);
    c.args([
        "--checkpoint",
        ck.to_str().unwrap(),
        "--checkpoint-every",
        "1",
        "--on-failure",
        on_failure,
        "--kernel",
        kernel,
        "--out",
        out.to_str().unwrap(),
    ]);
    c
}

/// The recovery-determinism guarantee, end to end: a 3-rank ring loses
/// rank 1 mid-run under `--on-failure shrink`; the survivors regroup,
/// roll back and COMPLETE (exit 0).  The test then reconstructs the
/// rollback election from the surviving attempt-0 checkpoints on disk,
/// merges them exactly as the recovery does, and replays the healed
/// attempt as a clean in-process 2-rank run from that state
/// (`train_tcp_ring_from`) — the healed embeddings must be
/// bitwise-equal to the replay's.  Exercised under both compute
/// kernels: recovery must not perturb training arithmetic.
#[test]
fn shrink_recovery_is_bitwise_deterministic() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    for kernel in ["fused", "gemm3"] {
        let f = fixture(&format!("shrink_{kernel}"));
        let addrs = ring_addrs(&free_ports(3));
        let surv0 = heal_rank_cmd(&f, 0, &addrs, "shrink", kernel)
            .spawn()
            .unwrap();
        let victim = heal_rank_cmd(&f, 1, &addrs, "shrink", kernel)
            .env("PW2V_FAULT", "kill-after=40")
            .spawn()
            .unwrap();
        let surv2 = heal_rank_cmd(&f, 2, &addrs, "shrink", kernel)
            .spawn()
            .unwrap();

        let out_victim = wait_deadline(victim, "killed rank", Duration::from_secs(60));
        assert_eq!(out_victim.status.code(), Some(42));
        for (rank, surv) in [(0usize, surv0), (2usize, surv2)] {
            let out = wait_deadline(surv, "healing survivor", Duration::from_secs(120));
            let err = String::from_utf8_lossy(&out.stderr);
            assert!(
                out.status.success(),
                "survivor rank {rank} failed instead of healing: {err}"
            );
            assert!(
                err.contains("regrouping") && err.contains("rolled back"),
                "rank {rank} stderr lacks the recovery trace: {err}"
            );
        }
        let (w0, emb0) = model_io::load_text(f.dir.join("vec0.txt").to_str().unwrap()).unwrap();
        let (w2, emb2) = model_io::load_text(f.dir.join("vec2.txt").to_str().unwrap()).unwrap();
        assert_eq!(w0, w2, "[{kernel}] survivors disagree on vocab order");
        assert_eq!(
            emb0.data(),
            emb2.data(),
            "[{kernel}] survivors' healed embeddings differ"
        );

        // --- Reconstruct the election the survivors performed. ---
        let ck_base = f.dir.join("ck");
        let cks: Vec<model_io::Checkpoint> = [0usize, 2]
            .iter()
            .map(|&r| {
                let latest = model_io::latest_checkpoint(&ck_base, r)
                    .unwrap_or_else(|| panic!("rank {r}: no attempt-0 checkpoint"));
                latest
            })
            .collect();
        let target = cks.iter().map(|c| c.round).min().unwrap();
        assert!(target > 0);
        // Exact-round load (two-slot retention guarantees availability).
        let at = |r: usize| -> model_io::Checkpoint {
            (0..2)
                .filter_map(|slot| {
                    model_io::load_checkpoint(model_io::checkpoint_slot_path(&ck_base, r, slot))
                        .ok()
                })
                .find(|c| c.round == target)
                .unwrap_or_else(|| panic!("rank {r}: no checkpoint at elected round {target}"))
        };
        let (ck0, ck2) = (at(0), at(2));
        let epochs_done = ck0.epoch.min(ck2.epoch) as usize;
        let words_base = ck0.words_done + ck2.words_done;
        let dim = ck0.m_in.dim();
        let vocab_rows = ck0.m_in.vocab();
        let merged = [
            SharedModel::new(ck0.m_in, ck0.m_out),
            SharedModel::new(ck2.m_in, ck2.m_out),
        ];
        let mut scratch = vec![0.0f32; dim];
        for r in 0..vocab_rows as u32 {
            average_row(&merged, r, &mut scratch);
        }

        // --- Replay the healed attempt as a clean 2-rank run. ---
        let mut cfg = TrainConfig::default();
        cfg.dim = 16;
        cfg.epochs = 2;
        cfg.min_count = 1;
        cfg.kernel = kernel.parse().unwrap();
        let vocab = Vocab::build_from_file(&f.corpus, cfg.min_count).unwrap();
        assert_eq!(vocab.len(), vocab_rows);
        let mut dist = DistConfig::for_nodes(3);
        dist.sync_interval = 4000;
        let net = NetConfig::default();
        let ref_base = f.dir.join("ck_ref");
        let listeners: Vec<TcpListener> = (0..2)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let ref_addrs: Vec<String> = listeners
            .iter()
            .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
            .collect();
        let outs: Vec<DistOutcome> = std::thread::scope(|scope| {
            let handles: Vec<_> = listeners
                .into_iter()
                .enumerate()
                .map(|(rank, l)| {
                    let (cfg, dist, vocab) = (cfg.clone(), dist.clone(), &vocab);
                    let (ref_addrs, ref_base) = (ref_addrs.clone(), ref_base.clone());
                    let start = AttemptStart {
                        model: SharedModel::new(
                            merged[0].m_in().clone(),
                            merged[0].m_out().clone(),
                        ),
                        epochs_done,
                        words_base,
                    };
                    let corpus = f.corpus.clone();
                    scope.spawn(move || {
                        let spec = RingSpec {
                            rank,
                            addrs: ref_addrs,
                        };
                        let ckpt = CheckpointPolicy {
                            base: Some(ref_base),
                            every: 1,
                            resume: false,
                        };
                        train_tcp_ring_from(
                            Some(l),
                            &cfg,
                            &dist,
                            &spec,
                            &net,
                            &ckpt,
                            &corpus,
                            vocab,
                            start,
                        )
                        .unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for out in &outs {
            assert_eq!(
                out.model.m_in().data(),
                emb0.data(),
                "[{kernel}] healed run differs from a clean run launched \
                 from the same rollback state"
            );
        }
    }
}

/// Rejoin round trip: under `--on-failure rejoin` the survivors hold
/// the regroup open for the grace window; a promptly respawned rank 1
/// (same argv, fault cleared) is re-admitted, the ORIGINAL 3-rank
/// membership is restored, and all three processes complete with
/// identical embeddings.
#[test]
fn rejoined_rank_is_readmitted_and_ring_completes() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("rejoin");
    let addrs = ring_addrs(&free_ports(3));
    let grace = ["--rejoin-grace-ms", "30000"];
    let surv0 = heal_rank_cmd(&f, 0, &addrs, "rejoin", "auto")
        .args(grace)
        .spawn()
        .unwrap();
    let victim = heal_rank_cmd(&f, 1, &addrs, "rejoin", "auto")
        .args(grace)
        .env("PW2V_FAULT", "kill-after=40")
        .spawn()
        .unwrap();
    let surv2 = heal_rank_cmd(&f, 2, &addrs, "rejoin", "auto")
        .args(grace)
        .spawn()
        .unwrap();

    let out_victim = wait_deadline(victim, "killed rank", Duration::from_secs(60));
    assert_eq!(out_victim.status.code(), Some(42));
    // Respawn rank 1 with the same argv, fault cleared: it must join
    // the regroup the survivors hold open and be re-admitted.
    let respawn = {
        let mut c = heal_rank_cmd(&f, 1, &addrs, "rejoin", "auto");
        c.args(grace);
        c.env_remove("PW2V_FAULT");
        c.spawn().unwrap()
    };

    let mut outs = Vec::new();
    for (rank, child) in [(0usize, surv0), (1, respawn), (2, surv2)] {
        let out = wait_deadline(child, "rejoin member", Duration::from_secs(120));
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            out.status.success(),
            "rank {rank} failed instead of healing: {err}"
        );
        if rank != 1 {
            assert!(
                err.contains("regrouping"),
                "rank {rank} stderr lacks the recovery trace: {err}"
            );
        }
        // Every member rolled back to the common round and reports the
        // RESTORED membership size.
        assert!(
            err.contains("rolled back") && err.contains("3 member(s)"),
            "rank {rank} did not report a 3-member healed view: {err}"
        );
        outs.push(model_io::load_text(f.dir.join(format!("vec{rank}.txt")).to_str().unwrap()));
    }
    let (w0, emb0) = outs.remove(0).unwrap();
    for out in outs {
        let (w, emb) = out.unwrap();
        assert_eq!(w0, w);
        assert_eq!(
            emb0.data(),
            emb.data(),
            "rejoin members disagree on the final embeddings"
        );
    }
}
