//! Deterministic fault-injection suite (`PW2V_FAULT`) against the
//! multi-process TCP ring: every failure mode the transport claims to
//! survive, exercised through real OS processes of the CLI binary.
//!
//! * `kill-after=N` — a rank exits hard (code 42) after N data frames:
//!   the survivor must exit non-zero within its i/o deadline and both
//!   ranks' checkpoints must remain loadable (crash consistency);
//! * `torn-frame=N` — a rank dies mid-frame (code 43), leaving a
//!   half-written frame on the wire: the receiver must reject the
//!   truncation, never parse garbage;
//! * `stall-after=N` — a rank wedges (alive, silent, heartbeats
//!   stopped): the peer's heartbeat deadline must fire;
//! * `panic-replica=I` — THREAD-mode: a panicking replica poisons the
//!   shared barrier and the whole process fails fast instead of
//!   deadlocking (the pre-PR hang this suite regression-pins).
//!
//! Scenarios are serialized by a file-local mutex.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::model::io as model_io;

static SERIAL: Mutex<()> = Mutex::new(());

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_pw2v")
}

fn free_ports(n: usize) -> Vec<u16> {
    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    listeners
        .iter()
        .map(|l| l.local_addr().unwrap().port())
        .collect()
}

fn ring_addrs(ports: &[u16]) -> String {
    ports
        .iter()
        .map(|p| format!("127.0.0.1:{p}"))
        .collect::<Vec<_>>()
        .join(",")
}

struct Fixture {
    dir: PathBuf,
    corpus: PathBuf,
}

impl Drop for Fixture {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.dir).ok();
    }
}

fn fixture(name: &str) -> Fixture {
    let dir = std::env::temp_dir().join(format!(
        "pw2v_dist_fault_{name}_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let mut scfg = SyntheticConfig::test_tiny();
    scfg.tokens = 60_000;
    scfg.seed = 113;
    let corpus = dir.join("corpus.txt");
    LatentModel::new(scfg).write_corpus(&corpus).unwrap();
    Fixture { dir, corpus }
}

/// One rank of a 2-rank ring on the fault fixture: small dim, many
/// rounds, tight failure-detection deadlines.
fn rank_cmd(corpus: &Path, rank: usize, addrs: &str) -> Command {
    let mut c = Command::new(bin());
    c.args([
        "train-dist",
        "--corpus",
        corpus.to_str().unwrap(),
        "--dist",
        &format!("tcp:{rank}@{addrs}"),
        "--min-count",
        "1",
        "--dim",
        "16",
        "--epochs",
        "2",
        "--sync-interval",
        "4000",
        "--net-timeout-ms",
        "4000",
        "--heartbeat-ms",
        "100",
    ]);
    c.stderr(Stdio::piped());
    c
}

fn wait_deadline(mut child: Child, what: &str, deadline: Duration) -> std::process::Output {
    let t0 = Instant::now();
    loop {
        if child.try_wait().unwrap().is_some() {
            return child.wait_with_output().unwrap();
        }
        if t0.elapsed() > deadline {
            child.kill().ok();
            child.wait().ok();
            panic!("{what} still running after {deadline:?}");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Kill one rank mid-run: the victim exits with the injected code, the
/// survivor exits non-zero within its deadline, and both ranks'
/// two-slot checkpoints are still loadable (atomic tmp+rename+fsync —
/// a crash can never leave a half-written "latest").
#[test]
fn killed_rank_fails_survivor_fast_and_checkpoints_stay_loadable() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("kill");
    let ck_base = f.dir.join("ck");
    let ck = ck_base.to_str().unwrap().to_string();
    let addrs = ring_addrs(&free_ports(2));
    let t0 = Instant::now();
    let surv = rank_cmd(&f.corpus, 0, &addrs)
        .args(["--checkpoint", &ck, "--checkpoint-every", "1"])
        .spawn()
        .unwrap();
    let victim = rank_cmd(&f.corpus, 1, &addrs)
        .args(["--checkpoint", &ck, "--checkpoint-every", "1"])
        .env("PW2V_FAULT", "kill-after=40")
        .spawn()
        .unwrap();

    let out_victim = wait_deadline(victim, "killed rank", Duration::from_secs(60));
    assert_eq!(out_victim.status.code(), Some(42));
    let out_surv = wait_deadline(surv, "survivor", Duration::from_secs(60));
    assert!(!out_surv.status.success(), "survivor must not succeed");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "survivor took {:?} to notice the dead peer",
        t0.elapsed()
    );
    let err = String::from_utf8_lossy(&out_surv.stderr);
    assert!(
        err.contains("error:"),
        "survivor exited silently: {err}"
    );

    for rank in 0..2 {
        let ck = model_io::latest_checkpoint(&ck_base, rank)
            .unwrap_or_else(|| panic!("rank {rank}: no loadable checkpoint after crash"));
        assert!(ck.round >= 1);
        assert_eq!(ck.m_in.dim(), 16);
    }
}

/// A torn frame (header promises more payload than ever arrives) must be
/// rejected as truncation by the receiving rank — never parsed as a
/// short-but-valid frame.
#[test]
fn torn_frame_is_rejected_not_parsed() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("torn");
    let addrs = ring_addrs(&free_ports(2));
    let surv = rank_cmd(&f.corpus, 0, &addrs).spawn().unwrap();
    let victim = rank_cmd(&f.corpus, 1, &addrs)
        .env("PW2V_FAULT", "torn-frame=10")
        .spawn()
        .unwrap();

    let out_victim = wait_deadline(victim, "torn rank", Duration::from_secs(60));
    assert_eq!(out_victim.status.code(), Some(43));
    let out_surv = wait_deadline(surv, "survivor", Duration::from_secs(60));
    assert!(!out_surv.status.success());
    let err = String::from_utf8_lossy(&out_surv.stderr);
    // Whichever the survivor hits first — the half frame (truncation) or
    // the dropped connection — it must be a transport diagnostic, not a
    // decode of garbage.
    assert!(
        err.contains("truncat") || err.contains("closed") || err.contains("silent"),
        "survivor error does not look like a transport failure: {err}"
    );
}

/// A stalled (wedged, not dead) peer stops heartbeating; the survivor's
/// read deadline must fire even though the TCP connection stays open.
#[test]
fn stalled_peer_trips_heartbeat_deadline() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("stall");
    let addrs = ring_addrs(&free_ports(2));
    let surv = rank_cmd(&f.corpus, 0, &addrs).spawn().unwrap();
    let stalled = rank_cmd(&f.corpus, 1, &addrs)
        .env("PW2V_FAULT", "stall-after=10")
        .spawn()
        .unwrap();

    let t0 = Instant::now();
    let out_surv = wait_deadline(surv, "survivor", Duration::from_secs(60));
    assert!(!out_surv.status.success());
    // Detection is deadline-based: must take at least roughly the i/o
    // timeout (nothing errored eagerly) and comfortably less than the
    // suite deadline.
    assert!(
        t0.elapsed() < Duration::from_secs(45),
        "deadline detection took {:?}",
        t0.elapsed()
    );
    let err = String::from_utf8_lossy(&out_surv.stderr);
    assert!(
        err.contains("silent") || err.contains("closed"),
        "expected a liveness diagnostic: {err}"
    );
    // The stalled process sleeps forever by design: reap it.
    let mut stalled = stalled;
    stalled.kill().ok();
    stalled.wait().ok();
}

/// Thread-mode fault wiring through the CLI: a panicking replica must
/// fail the whole process fast (poisoned barrier), not deadlock it.
#[test]
fn thread_mode_replica_panic_fails_process() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("panic");
    let child = Command::new(bin())
        .args([
            "train-dist",
            "--corpus",
            f.corpus.to_str().unwrap(),
            "--nodes",
            "2",
            "--min-count",
            "1",
            "--dim",
            "16",
            "--epochs",
            "1",
            "--sync-interval",
            "4000",
        ])
        .env("PW2V_FAULT", "panic-replica=1")
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let out = wait_deadline(child, "thread-mode run", Duration::from_secs(60));
    assert!(!out.status.success(), "panicking replica must fail the run");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("panic"), "stderr lacks the panic report: {err}");
}

/// Malformed `PW2V_FAULT` values are a startup error, not a silent
/// no-op — a typo'd fault spec in a harness must never "pass" by
/// accidentally running fault-free.
#[test]
fn malformed_fault_spec_is_refused_at_startup() {
    let _guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let f = fixture("badspec");
    let child = Command::new(bin())
        .args([
            "train-dist",
            "--corpus",
            f.corpus.to_str().unwrap(),
            "--nodes",
            "2",
            "--min-count",
            "1",
            "--dim",
            "16",
            "--epochs",
            "1",
        ])
        .env("PW2V_FAULT", "explode-eventually")
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let out = wait_deadline(child, "bad-spec run", Duration::from_secs(30));
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("PW2V_FAULT"), "stderr: {err}");
}
