//! Acceptance gate for the zero-allocation superbatch pipeline: at steady
//! state, filling the arena and processing it through the GEMM backend —
//! fused kernel and gemm3 chain alike — performs ZERO heap allocations
//! per window, INCLUDING when clipped-at-maximum sentences overshoot the
//! superbatch width (the sentence-slack arena sizing).
//!
//! A counting `#[global_allocator]` wraps `System`; after a warmup that
//! reaches every buffer's high-water capacity, further superbatch rounds
//! must leave the allocation counter untouched.  This file holds exactly
//! ONE test: other tests in the same binary would run on sibling threads
//! and allocate concurrently, poisoning the counter.
//!
//! Covers both ingest backends: the in-memory sentence fixtures (the
//! builder/backend pipeline alone) and the encoded `u32` corpus cache
//! (reader → builder → backend), whose per-epoch cursor re-creation must
//! also be allocation-free.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::HashMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};

use pw2v::config::{Backend as BackendKind, KernelMode, QuantMode, ReuseMode, SigmoidMode};
use pw2v::EncodedCorpus;
use pw2v::Vocab;
use pw2v::{StreamOptions, StreamTrainer, TrainConfig};
use pw2v::corpus::MAX_SENTENCE_LEN;
use pw2v::model::{Embedding, ShardMap, SharedModel};
use pw2v::serve::Scratch as ServeScratch;
use pw2v::{RowStore, ServeEngine};
use pw2v::sampling::batch::{BatchBuilder, SuperbatchArena};
use pw2v::sampling::unigram::UnigramSampler;
use pw2v::train::route::{Exchange, Outbox, RouteSink, RowRouter};
use pw2v::train::sgd_gemm::GemmBackend;
use pw2v::train::Backend;
use pw2v::util::rng::Xoshiro256ss;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_training_loop_allocates_nothing() {
    // Setup (allocates freely).
    let vocab_size = 500usize;
    let counts: HashMap<String, u64> = (0..vocab_size)
        .map(|i| (format!("w{i:04}"), (100_000 / (i + 1)) as u64))
        .collect();
    let vocab = Vocab::from_counts(counts, 1);
    let sampler = UnigramSampler::alias(&vocab, 0.75);
    let (dim, window, batch, negative, superbatch) = (64usize, 5usize, 16usize, 5usize, 32usize);
    let mut builder = BatchBuilder::new(&sampler, window, batch, negative);
    let model = SharedModel::init(vocab_size, dim, 7);
    let mut backend = GemmBackend::new(dim, batch, 1 + negative)
        .with_sigmoid(SigmoidMode::Exact);
    let mut arena = SuperbatchArena::with_capacity(superbatch, batch, 1 + negative);

    // Fixed sentence stream, replayed with a reseeded RNG each round so
    // every buffer sees identical id sequences (capacities stabilise
    // after round one).
    let sentences: Vec<Vec<u32>> = (0..12)
        .map(|s| {
            (0..60u32)
                .map(|i| (i.wrapping_mul(7).wrapping_add(s * 13)) % vocab_size as u32)
                .collect()
        })
        .collect();

    let round = |arena: &mut SuperbatchArena,
                 backend: &mut GemmBackend,
                 builder: &mut BatchBuilder| {
        let mut rng = Xoshiro256ss::new(99);
        for sent in &sentences {
            builder.fill_arena(sent, &mut rng, arena);
            if arena.len() >= superbatch {
                backend.process_arena(model.store(), arena, 0.025).unwrap();
                arena.clear();
            }
        }
        if !arena.is_empty() {
            backend.process_arena(model.store(), arena, 0.025).unwrap();
            arena.clear();
        }
    };

    // Warmup: reach the high-water capacity of every reused buffer.
    for _ in 0..3 {
        round(&mut arena, &mut backend, &mut builder);
    }

    let windows_per_round: usize = {
        let mut rng = Xoshiro256ss::new(99);
        let mut probe = SuperbatchArena::new(batch, 1 + negative);
        let mut n = 0;
        for sent in &sentences {
            builder.fill_arena(sent, &mut rng, &mut probe);
        }
        n += probe.len();
        n
    };
    assert!(windows_per_round > 500, "workload too small: {windows_per_round}");

    // Steady state: zero allocator calls over 50 rounds (~36k windows).
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..50 {
        round(&mut arena, &mut backend, &mut builder);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state loop allocated {} times over 50 superbatch rounds \
         ({windows_per_round} windows each)",
        after - before
    );

    // ------------------------------------------------------------------
    // Long-sentence corpus: sentences clipped at MAX_SENTENCE_LEN land in
    // the arena as ONE append of ~1000 windows, far past the superbatch
    // width.  The trainer's sentence-slack sizing must absorb that
    // without the arena ever reallocating — even on the VERY FIRST fill,
    // before any warmup (this is the regression the exactly-sized arena
    // had).
    // ------------------------------------------------------------------
    let long_sentences: Vec<Vec<u32>> = (0..3)
        .map(|s: u32| {
            (0..MAX_SENTENCE_LEN as u32)
                .map(|i| (i.wrapping_mul(11).wrapping_add(s * 29)) % vocab_size as u32)
                .collect()
        })
        .collect();
    let mut long_arena =
        SuperbatchArena::with_sentence_slack(superbatch, batch, 1 + negative);
    {
        let mut rng = Xoshiro256ss::new(123);
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        builder.fill_arena(&long_sentences[0], &mut rng, &mut long_arena);
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert!(long_arena.len() >= superbatch, "overshoot not exercised");
        assert_eq!(
            after - before,
            0,
            "sentence-slack arena reallocated on a first-fill overshoot \
             ({} windows)",
            long_arena.len()
        );
        long_arena.clear();
    }

    // Both kernel organisations must be allocation-free at steady state on
    // the long-sentence stream (fused is the default hot path; gemm3 is
    // the preserved ablation chain).
    for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
        let mut backend = GemmBackend::new(dim, batch, 1 + negative)
            .with_sigmoid(SigmoidMode::Exact)
            .with_kernel(kernel);
        let long_round =
            |arena: &mut SuperbatchArena,
             backend: &mut GemmBackend,
             builder: &mut BatchBuilder| {
                let mut rng = Xoshiro256ss::new(321);
                for sent in &long_sentences {
                    builder.fill_arena(sent, &mut rng, arena);
                    if arena.len() >= superbatch {
                        backend.process_arena(model.store(), arena, 0.025).unwrap();
                        arena.clear();
                    }
                }
                if !arena.is_empty() {
                    backend.process_arena(model.store(), arena, 0.025).unwrap();
                    arena.clear();
                }
            };
        // Warmup reaches the backend scratch high-water (wo_uniq etc.).
        for _ in 0..3 {
            long_round(&mut long_arena, &mut backend, &mut builder);
        }
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..20 {
            long_round(&mut long_arena, &mut backend, &mut builder);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state long-sentence loop allocated {} times \
             (kernel {kernel:?})",
            after - before
        );
    }

    // ------------------------------------------------------------------
    // Reuse leg (PR 10): `--reuse sentence` — the run-grouping driver
    // (sentence-shared negative draws, run gather, `sgns_fused_run`,
    // deferred input scatter) must also be allocation-free at steady
    // state, for both kernel organisations.  All run scratch (the
    // RUN_CAP-wide wi/dwi/logits blocks, the run offsets) is sized at
    // construction by `with_reuse`.
    // ------------------------------------------------------------------
    let mut reuse_builder = BatchBuilder::new(&sampler, window, batch, negative)
        .with_reuse(ReuseMode::Sentence);
    for kernel in [KernelMode::Fused, KernelMode::Gemm3] {
        let mut backend = GemmBackend::new(dim, batch, 1 + negative)
            .with_sigmoid(SigmoidMode::Exact)
            .with_kernel(kernel)
            .with_reuse(ReuseMode::Sentence);
        for _ in 0..3 {
            round(&mut arena, &mut backend, &mut reuse_builder);
        }
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..20 {
            round(&mut arena, &mut backend, &mut reuse_builder);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state REUSE (sentence) loop allocated {} times over 20 \
             rounds (kernel {kernel:?})",
            after - before
        );
    }

    // ------------------------------------------------------------------
    // Encoded-corpus leg: the cached ingest path (EncodedSentenceReader →
    // fill_arena → process_arena) must ALSO be allocation-free per window
    // at steady state — including opening a fresh range cursor every
    // round, which is exactly what the trainer does per epoch.
    // ------------------------------------------------------------------
    let text_path = std::env::temp_dir().join(format!(
        "pw2v_alloc_enc_{}.txt",
        std::process::id()
    ));
    {
        // Materialise the fixture stream as a real text corpus (ids →
        // words roundtrip through the same vocab).
        let mut f = std::fs::File::create(&text_path).unwrap();
        for sent in &sentences {
            let line: Vec<&str> =
                sent.iter().map(|&id| vocab.word(id)).collect();
            writeln!(f, "{}", line.join(" ")).unwrap();
        }
    }
    let cache_path = EncodedCorpus::cache_path_for(&text_path);
    EncodedCorpus::build(&text_path, &vocab, &cache_path).unwrap();
    let enc = EncodedCorpus::open(&cache_path, &vocab).unwrap();
    assert_eq!(enc.n_sentences(), sentences.len() as u64);

    let mut backend = GemmBackend::new(dim, batch, 1 + negative)
        .with_sigmoid(SigmoidMode::Exact);
    let mut sent_buf: Vec<u32> = Vec::with_capacity(MAX_SENTENCE_LEN);
    let enc_round = |arena: &mut SuperbatchArena,
                     backend: &mut GemmBackend,
                     builder: &mut BatchBuilder,
                     sent_buf: &mut Vec<u32>| {
        let mut rng = Xoshiro256ss::new(99);
        let mut reader = enc.reader_range(0, enc.text_len());
        while reader.next_sentence_into(sent_buf).unwrap() {
            builder.fill_arena(sent_buf, &mut rng, arena);
            if arena.len() >= superbatch {
                backend.process_arena(model.store(), arena, 0.025).unwrap();
                arena.clear();
            }
        }
        if !arena.is_empty() {
            backend.process_arena(model.store(), arena, 0.025).unwrap();
            arena.clear();
        }
    };
    for _ in 0..3 {
        enc_round(&mut arena, &mut backend, &mut builder, &mut sent_buf);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..50 {
        enc_round(&mut arena, &mut backend, &mut builder, &mut sent_buf);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state ENCODED-corpus loop allocated {} times over 50 \
         rounds (reader re-created each round)",
        after - before
    );
    std::fs::remove_file(&text_path).ok();
    std::fs::remove_file(&cache_path).ok();

    // ------------------------------------------------------------------
    // Routed-exchange leg (`--route`): one thread drives BOTH sides of a
    // two-worker exchange — producer 0 classifies windows through the
    // RouteSink (head = whole vocab, two-node map ⇒ high-id targets go
    // through the mailbox to "worker" 1), consumer 1 adopts the blocks
    // into its route-slack arena.  After a warmup that circulates every
    // block and reaches the backend high-water, the routed pipeline must
    // allocate NOTHING: blocks recycle through the free rings, adoption
    // is a capacity-held `append_from`, and both arenas were sized with
    // `with_route_slack`.
    // ------------------------------------------------------------------
    let router = RowRouter::new(
        ShardMap::contiguous(vocab_size, 2),
        vocab_size, // route the whole id space: node-1 rows go remote
    );
    let exch = Exchange::new(2, 2, 16, batch, 1 + negative);
    let mut backend0 = GemmBackend::new(dim, batch, 1 + negative)
        .with_sigmoid(SigmoidMode::Exact);
    let mut backend1 = GemmBackend::new(dim, batch, 1 + negative)
        .with_sigmoid(SigmoidMode::Exact);
    let mut arena0 = SuperbatchArena::with_route_slack(
        superbatch,
        batch,
        1 + negative,
        exch.max_inflight(),
    );
    let mut arena1 = SuperbatchArena::with_route_slack(
        superbatch,
        batch,
        1 + negative,
        exch.max_inflight(),
    );
    let mut outbox = Outbox::new(&exch, &router, 0);
    let routed_round = |a0: &mut SuperbatchArena,
                        a1: &mut SuperbatchArena,
                        b0: &mut GemmBackend,
                        b1: &mut GemmBackend,
                        builder: &mut BatchBuilder,
                        ob: &mut Outbox<'_>| {
        let mut rng = Xoshiro256ss::new(77);
        for sent in &sentences {
            {
                let mut sink = RouteSink::new(a0, ob);
                builder.fill_arena_routed(sent, &mut rng, &mut sink);
            }
            if a0.len() >= superbatch {
                ob.flush();
                b0.process_arena(model.store(), a0, 0.025).unwrap();
                a0.clear();
            }
            exch.drain_into(1, a1);
            if a1.len() >= superbatch {
                b1.process_arena(model.store(), a1, 0.025).unwrap();
                a1.clear();
            }
        }
        ob.flush();
        exch.drain_into(1, a1);
        if !a0.is_empty() {
            b0.process_arena(model.store(), a0, 0.025).unwrap();
            a0.clear();
        }
        if !a1.is_empty() {
            b1.process_arena(model.store(), a1, 0.025).unwrap();
            a1.clear();
        }
    };
    for _ in 0..3 {
        routed_round(
            &mut arena0,
            &mut arena1,
            &mut backend0,
            &mut backend1,
            &mut builder,
            &mut outbox,
        );
    }
    assert!(
        outbox.routed_windows > 0,
        "routed leg exercised no mailbox traffic"
    );
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..20 {
        routed_round(
            &mut arena0,
            &mut arena1,
            &mut backend0,
            &mut backend1,
            &mut builder,
            &mut outbox,
        );
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state ROUTED loop allocated {} times over 20 rounds \
         (mailbox blocks must recycle allocation-free)",
        after - before
    );

    // ------------------------------------------------------------------
    // Serve leg (PR 8): the request/response path of the embedding
    // server — pull-parse, SIMD scan (f32 AND int8), hit selection,
    // JSON response writing — must allocate NOTHING at steady state.
    // Every buffer lives in the caller-owned serve Scratch; warmup
    // reaches each one's high-water capacity (including the error
    // paths, which a hostile client can drive at line rate).
    // ------------------------------------------------------------------
    let (sv, sd) = (300usize, 32usize);
    let mut semb = Embedding::zeros(sv, sd);
    {
        let mut rng = Xoshiro256ss::new(4242);
        for id in 0..sv as u32 {
            for x in semb.row_mut(id) {
                *x = rng.next_f32() - 0.5;
            }
        }
    }
    let swords: Vec<String> = (0..sv).map(|i| format!("s{i:04}")).collect();
    let serve_reqs: [&[u8]; 4] = [
        br#"{"op":"topk","word":"s0007","k":10}"#,
        br#"{"op":"analogy","a":"s0001","b":"s0002","c":"s0003","k":5}"#,
        br#"{"op":"topk","word":"no-such-word"}"#,
        br#"{"op":"frobnicate"}"#,
    ];
    for quant in [QuantMode::Off, QuantMode::Int8] {
        let eng = ServeEngine::from_store(
            RowStore::from_model(swords.clone(), &semb).unwrap(),
            quant,
        )
        .unwrap();
        let mut scratch = ServeScratch::default();
        for _ in 0..3 {
            for r in serve_reqs {
                eng.handle_line(r, &mut scratch);
            }
        }
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..100 {
            for r in serve_reqs {
                eng.handle_line(r, &mut scratch);
                assert!(!scratch.out.is_empty());
            }
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert_eq!(
            after - before,
            0,
            "steady-state SERVE loop (quant {quant:?}) allocated {} times \
             over 400 requests",
            after - before
        );
    }

    // ------------------------------------------------------------------
    // Streaming leg (PR 9): the ingest→train loop — tail read into the
    // reused line buffer, tokenize, subsample, fill_arena, flush — must
    // allocate NOTHING at steady state while every arriving word is
    // known.  Allocation is permitted ONLY on admission events (OOV
    // candidate bookkeeping, the alias-table rebuild on admit); after an
    // admission the loop must return to zero.  The growth schedule is
    // appended up front and replayed through explicit poll limits so
    // the measured window performs no file writes of its own.
    // ------------------------------------------------------------------
    let stream_path = std::env::temp_dir().join(format!(
        "pw2v_alloc_stream_{}.txt",
        std::process::id()
    ));
    let fixture_block: String = {
        let mut s = String::new();
        for sent in &sentences {
            let line: Vec<&str> = sent.iter().map(|&id| vocab.word(id)).collect();
            s.push_str(&line.join(" "));
            s.push('\n');
        }
        s
    };
    std::fs::write(&stream_path, &fixture_block).unwrap();
    let seed_len = std::fs::metadata(&stream_path).unwrap().len();

    let mut scfg = TrainConfig::test_tiny();
    scfg.backend = BackendKind::Gemm;
    scfg.threads = 1;
    scfg.epochs = 1;
    scfg.sample = 1e-3;
    scfg.seed = 7;
    scfg.vocab_reserve = 16; // admission armed, so its no-op cost is measured
    let mut tr = StreamTrainer::open(&scfg, &stream_path, StreamOptions::default())
        .unwrap();
    assert!(tr.poll_once(seed_len).unwrap());

    // Phase 1: 30 known-vocab growth rounds.  Phase 2: an OOV burst.
    // Phase 3: 10 more known-vocab rounds after the admission.
    let mut appender = std::fs::OpenOptions::new()
        .append(true)
        .open(&stream_path)
        .unwrap();
    let mut limits: Vec<u64> = Vec::new();
    let mut end = seed_len;
    for _ in 0..30 {
        appender.write_all(fixture_block.as_bytes()).unwrap();
        end += fixture_block.len() as u64;
        limits.push(end);
    }
    let oov_line = format!("novelalpha novelbeta {}", fixture_block.lines().next().unwrap());
    appender.write_all(oov_line.as_bytes()).unwrap();
    appender.write_all(b"\n").unwrap();
    end += oov_line.len() as u64 + 1;
    let oov_limit = end;
    let mut limits_after: Vec<u64> = Vec::new();
    for _ in 0..10 {
        appender.write_all(fixture_block.as_bytes()).unwrap();
        end += fixture_block.len() as u64;
        limits_after.push(end);
    }
    drop(appender);

    for l in &limits[..5] {
        tr.poll_once(*l).unwrap(); // warmup: line buffer + backend high-water
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for l in &limits[5..] {
        tr.poll_once(*l).unwrap();
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state STREAM ingest→train loop allocated {} times over 25 \
         known-vocab growth rounds",
        after - before
    );

    // Admission event: first poll observes the OOV pair, second admits
    // them (allocations here are the allowed admission cost).
    tr.poll_once(oov_limit).unwrap();
    tr.poll_once(oov_limit).unwrap();
    assert_eq!(
        tr.snapshot().admissions,
        2,
        "OOV burst was not admitted (candidates: observe → admit)"
    );

    // Back to zero after the admission: the rebuilt tables are reused.
    for l in &limits_after[..3] {
        tr.poll_once(*l).unwrap();
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for l in &limits_after[3..] {
        tr.poll_once(*l).unwrap();
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "post-admission STREAM loop allocated {} times over 7 known-vocab \
         rounds (admission cost must not leak into steady state)",
        after - before
    );
    std::fs::remove_file(&stream_path).ok();
}
