//! API-surface stub of the vendored `xla` crate — the exact subset the
//! `pjrt` feature of `pw2v` consumes (`runtime/client.rs`,
//! `runtime/executable.rs`), with every constructor returning a clean
//! runtime error.
//!
//! Purpose: CI can `cargo check --features pjrt` so the pjrt-gated rust
//! code stops relying on default-feature builds to catch rot, without
//! shipping the XLA toolchain.  The handle types are uninhabited enums,
//! so all post-construction methods are statically unreachable: if the
//! stub is linked into a running binary, the only observable behaviour
//! is `PjRtClient::cpu()` (and `HloModuleProto::from_text_file`)
//! reporting that real PJRT support is not linked in — the same
//! degraded-gracefully story as `pw2v`'s `runtime::stub`.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's `xla::Error` usage (`Display` in
/// `map_err` wrappers).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "xla stub: real PJRT bindings not linked (point the `xla` path \
         dependency in rust/Cargo.toml at the vendored crate)"
            .to_string(),
    )
}

/// Uninhabited handle: no stub client can ever exist.
pub enum PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match *self {}
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        match *self {}
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        match *self {}
    }
}

pub enum HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self, Error> {
        Err(unavailable())
    }
}

pub enum XlaComputation {}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match *proto {}
    }
}

pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        match *self {}
    }

    pub fn execute_b(
        &self,
        _args: &[PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match *self {}
    }
}

pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match *self {}
    }
}

pub enum Literal {}

impl Literal {
    pub fn to_tuple2(self) -> Result<(Literal, Literal), Error> {
        match self {}
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }
}
