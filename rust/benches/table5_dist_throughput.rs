//! Table V — best distributed throughput comparison (paper Sec. IV-C).
//!
//! MODELLED: the cluster cost model at the paper's best configurations
//! (4/32 BDW over FDR, 4/16 KNL over OPA).  QUOTED: BIDMach's 4-GPU
//! number.  REAL: local aggregate throughput of the actual protocol at
//! small N on this box, reported for transparency (1 vCPU ⇒ replica
//! threads time-share; the protocol cost, not the parallel speedup, is
//! what's measurable here).

use pw2v::bench::{standard_workload, BenchTable};
use pw2v::config::TrainConfig;
use pw2v::dist::{train_distributed, DistConfig};
use pw2v::perfmodel::arch;
use pw2v::perfmodel::simulate::{fig4_series, FigParams};
use pw2v::util::si;

fn main() -> anyhow::Result<()> {
    let p = FigParams::default();
    let nodes = [4usize, 16, 32];
    let bdw = fig4_series(
        &arch::broadwell(),
        arch::fdr_infiniband(),
        &p,
        182_000.0,
        &nodes,
    );
    let knl = fig4_series(&arch::knl(), arch::omnipath(), &p, 85_000.0, &nodes);

    let mut table = BenchTable::new(
        "table5_dist_throughput",
        &["system", "node_count", "code", "words_per_sec", "source"],
    );
    table.row(vec![
        "Nvidia Titan-X GPU".into(),
        "4".into(),
        "BIDMach".into(),
        si(20e6),
        "quoted [10]".into(),
    ]);
    table.row(vec![
        "Intel Broadwell CPU".into(),
        "4".into(),
        "Our".into(),
        si(bdw[0].words_per_sec),
        "modelled".into(),
    ]);
    table.row(vec![
        "Intel Knights Landing".into(),
        "4".into(),
        "Our".into(),
        si(knl[0].words_per_sec),
        "modelled".into(),
    ]);
    table.row(vec![
        "Intel Broadwell CPU".into(),
        "32".into(),
        "Our".into(),
        si(bdw[2].words_per_sec),
        "modelled".into(),
    ]);
    table.row(vec![
        "Intel Knights Landing".into(),
        "16".into(),
        "Our".into(),
        si(knl[1].words_per_sec),
        "modelled".into(),
    ]);
    table.finish()?;
    println!(
        "\npaper Table V: BIDMach 4-GPU 20M; Our 4-BDW 20M, 4-KNL 29.4M,\n\
         32-BDW 110M, 16-KNL 94.7M words/s"
    );

    // Real protocol run on this box (wall-clock, time-shared vCPU).
    let wl = standard_workload()?;
    let mut real = BenchTable::new(
        "table5_protocol_local",
        &["nodes", "aggregate_wps_local", "wire_bytes_per_node"],
    );
    for n in [1usize, 2, 4] {
        let mut cfg = TrainConfig::default();
        cfg.dim = 100;
        cfg.sample = 1e-3;
        let mut dist = DistConfig::for_nodes(n);
        dist.sync_interval = 100_000;
        let out = train_distributed(&cfg, &dist, &wl.corpus, &wl.vocab)?;
        real.row(vec![
            n.to_string(),
            si(out.words as f64 / out.secs.max(1e-9)),
            si(out.sync_stats[0].wire_bytes as f64),
        ]);
    }
    real.finish()?;
    Ok(())
}
