//! Fig. 3 — thread scalability of the original word2vec vs our scheme on
//! a dual-socket Broadwell (paper Sec. IV-B).
//!
//! What is REAL here: single-thread throughput of each back-end, measured
//! on this box (the paper's 1T speedup claim, ~2.6×), the fused-vs-gemm3
//! window-kernel ablation at thread scale, plus honest multi-thread
//! measurements (this box exposes one vCPU, so they are flat — reported
//! anyway for transparency).  What is MODELLED: the 1–72 thread curve,
//! projected through the calibrated coherence model
//! (rust/src/perfmodel/cache.rs), anchored on the paper's 1T rates; the
//! measured ratio on this box validates the anchor gap.
//!
//! `cargo bench --bench fig3_thread_scaling -- --json` merges the
//! measured words/sec rows (backend × kernel × simd × threads) into
//! `BENCH_throughput.json` at the repo root.

use pw2v::bench::{standard_workload, BenchTable, ThroughputReport};
use pw2v::config::{Backend, KernelMode, TrainConfig};
use pw2v::linalg::simd::SimdMode;
use pw2v::model::SharedModel;
use pw2v::perfmodel::arch::broadwell;
use pw2v::perfmodel::simulate::{fig3_series, fig3_thread_axis, FigParams};
use pw2v::runtime::topology::{NumaMode, Topology};
use pw2v::train;
use pw2v::train::route::RouteMode;
use pw2v::util::args::Args;
use pw2v::util::json::Json;
use pw2v::util::si;

/// One `fig3_throughput` JSON row: trainer-level words/sec for a
/// (backend × kernel × simd × threads) point.
fn json_row(
    backend: &str,
    kernel: &str,
    simd: &str,
    threads: usize,
    wps: f64,
) -> Json {
    Json::obj([
        ("backend", Json::str(backend)),
        ("kernel", Json::str(kernel)),
        ("simd", Json::str(simd)),
        ("threads", Json::Num(threads as f64)),
        ("words_per_sec", Json::num(wps)),
    ])
}

fn measure_cfg(
    backend: Backend,
    threads: usize,
    simd: SimdMode,
    kernel: KernelMode,
    numa: NumaMode,
    route: RouteMode,
    wl: &pw2v::bench::Workload,
) -> f64 {
    let mut cfg = TrainConfig::default();
    cfg.backend = backend;
    cfg.threads = threads;
    cfg.dim = 300;
    cfg.sample = 1e-4;
    cfg.simd = simd;
    cfg.kernel = kernel;
    cfg.numa = numa;
    cfg.route = route;
    let model = SharedModel::init(wl.vocab.len(), cfg.dim, cfg.seed);
    let out = train::train(&cfg, &wl.corpus, &wl.vocab, &model).unwrap();
    out.snapshot.words_per_sec()
}

fn measure_simd(
    backend: Backend,
    threads: usize,
    simd: SimdMode,
    wl: &pw2v::bench::Workload,
) -> f64 {
    measure_cfg(
        backend,
        threads,
        simd,
        KernelMode::Auto,
        NumaMode::Off,
        RouteMode::Off,
        wl,
    )
}

fn measure(backend: Backend, threads: usize, wl: &pw2v::bench::Workload) -> f64 {
    measure_simd(backend, threads, SimdMode::Auto, wl)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env_tail(1);
    let mut report = args.flag("json").then(ThroughputReport::open_at_repo_root);
    let mut json_rows: Vec<Json> = Vec::new();
    let wl = standard_workload()?;
    eprintln!(
        "corpus: {} tokens, vocab {}",
        wl.vocab.total_words(),
        wl.vocab.len()
    );
    let hw_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    // Fused-vs-gemm3 kernel ablation at trainer level: the SAME GEMM
    // trainer, same dispatch, only the window-kernel organisation
    // differs (the fused-kernel PR's tentpole measurement, also at
    // thread scale).
    let mut kern = BenchTable::new(
        "fig3_kernel_ablation",
        &["threads", "fused_wps", "gemm3_wps", "fused_over_gemm3"],
    );
    // (t, fused words/sec) — reused below so the gemm/fused/auto config is
    // trained ONCE per thread count and lands in the JSON exactly once.
    let mut fused_by_t: Vec<(usize, f64)> = Vec::new();
    for t in [1usize, 2, 4] {
        if t > 2 * hw_threads {
            break;
        }
        let wf = measure_cfg(
            Backend::Gemm,
            t,
            SimdMode::Auto,
            KernelMode::Fused,
            NumaMode::Off,
            RouteMode::Off,
            &wl,
        );
        let wg = measure_cfg(
            Backend::Gemm,
            t,
            SimdMode::Auto,
            KernelMode::Gemm3,
            NumaMode::Off,
            RouteMode::Off,
            &wl,
        );
        fused_by_t.push((t, wf));
        kern.row(vec![
            t.to_string(),
            si(wf),
            si(wg),
            format!("{:.2}x", wf / wg.max(1.0)),
        ]);
        json_rows.push(json_row("gemm", "fused", "auto", t, wf));
        json_rows.push(json_row("gemm", "gemm3", "auto", t, wg));
        if t == 1 {
            println!(
                "fused over gemm3 at 1T: {:.2}x (acceptance floor 1.3x)",
                wf / wg.max(1.0)
            );
        }
    }
    kern.finish()?;

    // NUMA pinning leg: the SAME gemm/fused/auto trainer with the model
    // sharded + workers pinned (`--numa auto`) vs the flat unpinned path
    // (`--numa off`, rows reused from the kernel ablation above).  On a
    // one-node box the ratio is ~1.0 by construction (the sharded path
    // adds only the shard-map lookup); the separation appears on
    // multi-socket runners, where BENCH_throughput.json tracks it.
    let topo_nodes = Topology::detect().map(|t| t.nodes()).unwrap_or(1);
    let mut numa_tbl = BenchTable::new(
        "fig3_numa_pinning",
        &["threads", "numa_off_wps", "numa_auto_wps", "auto_over_off"],
    );
    let mut numa_rows: Vec<Json> = Vec::new();
    // (t, numa-auto words/sec) — the `--route` ablation's unrouted
    // baseline below (one training run per configuration).
    let mut auto_by_t: Vec<(usize, f64)> = Vec::new();
    for &(t, w_off) in &fused_by_t {
        let w_auto = measure_cfg(
            Backend::Gemm,
            t,
            SimdMode::Auto,
            KernelMode::Fused,
            NumaMode::Auto,
            RouteMode::Off,
            &wl,
        );
        auto_by_t.push((t, w_auto));
        numa_tbl.row(vec![
            t.to_string(),
            si(w_off),
            si(w_auto),
            format!("{:.2}x", w_auto / w_off.max(1.0)),
        ]);
        numa_rows.push(Json::obj([
            ("threads", Json::Num(t as f64)),
            ("nodes", Json::Num(topo_nodes as f64)),
            ("numa_off_wps", Json::num(w_off)),
            ("numa_auto_wps", Json::num(w_auto)),
            ("auto_over_off", Json::num(w_auto / w_off.max(1.0))),
        ]));
    }
    numa_tbl.finish()?;
    println!(
        "numa pinning leg measured on {topo_nodes} node(s) — ratios separate \
         only on multi-socket machines"
    );

    // Routing ablation: the SAME gemm/fused trainer under `--numa auto`,
    // with windows ownership-routed (`--route owner`) vs unrouted.  On a
    // one-node box the ratio IS the exchange overhead (mailbox hops buy
    // no locality); the win appears on multi-socket runners, where the
    // routed head keeps hot output rows on their home socket —
    // BENCH_throughput.json tracks both via `fig3_route`.
    let mut route_tbl = BenchTable::new(
        "fig3_route_ablation",
        &["threads", "route_off_wps", "route_owner_wps", "routed_over_unrouted"],
    );
    let mut route_rows: Vec<Json> = Vec::new();
    for &(t, w_unrouted) in &auto_by_t {
        let w_routed = measure_cfg(
            Backend::Gemm,
            t,
            SimdMode::Auto,
            KernelMode::Fused,
            NumaMode::Auto,
            RouteMode::Owner,
            &wl,
        );
        route_tbl.row(vec![
            t.to_string(),
            si(w_unrouted),
            si(w_routed),
            format!("{:.2}x", w_routed / w_unrouted.max(1.0)),
        ]);
        route_rows.push(Json::obj([
            ("threads", Json::Num(t as f64)),
            ("nodes", Json::Num(topo_nodes as f64)),
            ("route_off_wps", Json::num(w_unrouted)),
            ("route_owner_wps", Json::num(w_routed)),
            (
                "routed_over_unrouted",
                Json::num(w_routed / w_unrouted.max(1.0)),
            ),
        ]));
    }
    route_tbl.finish()?;
    println!(
        "route ablation measured on {topo_nodes} node(s) — the locality win \
         needs a multi-socket runner; here the ratio bounds exchange overhead"
    );

    // Kernel-dispatch ablation: the SAME GEMM trainer, explicit-AVX2 vs
    // pinned-scalar kernels, end to end (the tentpole's speedup measured
    // at trainer level, not just in microbenches).
    let mut dispatch = BenchTable::new(
        "fig3_simd_dispatch",
        &["simd", "gemm_wps_1t", "speedup_vs_scalar"],
    );
    let w_scalar = measure_simd(Backend::Gemm, 1, SimdMode::Scalar, &wl);
    // gemm/fused/auto at 1T was already measured by the kernel ablation.
    let w_auto = fused_by_t
        .iter()
        .find(|(t, _)| *t == 1)
        .map(|&(_, w)| w)
        .unwrap_or_else(|| measure(Backend::Gemm, 1, &wl));
    dispatch.row(vec!["scalar".into(), si(w_scalar), "1.00x".into()]);
    dispatch.row(vec![
        "auto".into(),
        si(w_auto),
        format!("{:.2}x", w_auto / w_scalar.max(1.0)),
    ]);
    dispatch.finish()?;
    json_rows.push(json_row("gemm", "fused", "scalar", 1, w_scalar));

    // Real measurements on this box (gemm numbers reused from the kernel
    // ablation — one training run per configuration).
    let mut measured = BenchTable::new(
        "fig3_measured_this_box",
        &["threads", "original_wps", "ours_wps", "speedup"],
    );
    let mut w1_scalar = 0.0;
    let mut w1_gemm = 0.0;
    for &(t, g) in &fused_by_t {
        let s = measure(Backend::Scalar, t, &wl);
        if t == 1 {
            w1_scalar = s;
            w1_gemm = g;
        }
        measured.row(vec![
            t.to_string(),
            si(s),
            si(g),
            format!("{:.2}x", g / s),
        ]);
        json_rows.push(json_row("scalar", "-", "auto", t, s));
    }
    measured.finish()?;
    println!(
        "\nmeasured 1-thread speedup (paper claims 2.6x): {:.2}x",
        w1_gemm / w1_scalar
    );

    // Modelled Fig. 3 curve: calibrated coherence model, anchored at the
    // paper's Broadwell 1T rates (our measured RATIO validates the gap;
    // absolute per-core speed of this vCPU differs from a 2.3 GHz BDW).
    let bdw = broadwell();
    let p = FigParams::default();
    let axis = fig3_thread_axis(&bdw);
    let (scalar_curve, gemm_curve) =
        fig3_series(&bdw, &p, 70_000.0, 182_000.0, &axis);
    let mut modelled = BenchTable::new(
        "fig3_modelled_bdw",
        &["threads", "original_wps", "ours_wps", "speedup"],
    );
    for (s, g) in scalar_curve.iter().zip(&gemm_curve) {
        modelled.row(vec![
            s.x.to_string(),
            si(s.words_per_sec),
            si(g.words_per_sec),
            format!("{:.2}x", g.words_per_sec / s.words_per_sec),
        ]);
    }
    modelled.finish()?;
    println!(
        "\npaper anchors: original 1.6M words/s @72T, ours 5.8M words/s @72T (3.6x)"
    );
    if let Some(r) = report.as_mut() {
        r.set("fig3_throughput", Json::Arr(json_rows));
        r.set("fig3_numa", Json::Arr(numa_rows));
        r.set("fig3_route", Json::Arr(route_rows));
        r.save()?;
    }
    Ok(())
}
