//! Fig. 3 — thread scalability of the original word2vec vs our scheme on
//! a dual-socket Broadwell (paper Sec. IV-B).
//!
//! What is REAL here: single-thread throughput of each back-end, measured
//! on this box (the paper's 1T speedup claim, ~2.6×), plus honest
//! multi-thread measurements (this box exposes one vCPU, so they are flat
//! — reported anyway for transparency).  What is MODELLED: the 1–72
//! thread curve, projected through the calibrated coherence model
//! (rust/src/perfmodel/cache.rs), anchored on the paper's 1T rates; the
//! measured ratio on this box validates the anchor gap.

use pw2v::bench::{standard_workload, BenchTable};
use pw2v::config::{Backend, TrainConfig};
use pw2v::linalg::simd::SimdMode;
use pw2v::model::SharedModel;
use pw2v::perfmodel::arch::broadwell;
use pw2v::perfmodel::simulate::{fig3_series, fig3_thread_axis, FigParams};
use pw2v::train;
use pw2v::util::si;

fn measure_simd(
    backend: Backend,
    threads: usize,
    simd: SimdMode,
    wl: &pw2v::bench::Workload,
) -> f64 {
    let mut cfg = TrainConfig::default();
    cfg.backend = backend;
    cfg.threads = threads;
    cfg.dim = 300;
    cfg.sample = 1e-4;
    cfg.simd = simd;
    let model = SharedModel::init(wl.vocab.len(), cfg.dim, cfg.seed);
    let out = train::train(&cfg, &wl.corpus, &wl.vocab, &model).unwrap();
    out.snapshot.words_per_sec()
}

fn measure(backend: Backend, threads: usize, wl: &pw2v::bench::Workload) -> f64 {
    measure_simd(backend, threads, SimdMode::Auto, wl)
}

fn main() -> anyhow::Result<()> {
    let wl = standard_workload()?;
    eprintln!(
        "corpus: {} tokens, vocab {}",
        wl.vocab.total_words(),
        wl.vocab.len()
    );

    // Kernel-dispatch ablation: the SAME GEMM trainer, explicit-AVX2 vs
    // pinned-scalar kernels, end to end (the tentpole's speedup measured
    // at trainer level, not just in microbenches).
    let mut dispatch = BenchTable::new(
        "fig3_simd_dispatch",
        &["simd", "gemm_wps_1t", "speedup_vs_scalar"],
    );
    let w_scalar = measure_simd(Backend::Gemm, 1, SimdMode::Scalar, &wl);
    let w_auto = measure_simd(Backend::Gemm, 1, SimdMode::Auto, &wl);
    dispatch.row(vec!["scalar".into(), si(w_scalar), "1.00x".into()]);
    dispatch.row(vec![
        "auto".into(),
        si(w_auto),
        format!("{:.2}x", w_auto / w_scalar.max(1.0)),
    ]);
    dispatch.finish()?;

    // Real measurements on this box.
    let mut measured = BenchTable::new(
        "fig3_measured_this_box",
        &["threads", "original_wps", "ours_wps", "speedup"],
    );
    let hw_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut w1_scalar = 0.0;
    let mut w1_gemm = 0.0;
    for t in [1usize, 2, 4] {
        if t > 2 * hw_threads {
            break;
        }
        let s = measure(Backend::Scalar, t, &wl);
        let g = measure(Backend::Gemm, t, &wl);
        if t == 1 {
            w1_scalar = s;
            w1_gemm = g;
        }
        measured.row(vec![
            t.to_string(),
            si(s),
            si(g),
            format!("{:.2}x", g / s),
        ]);
    }
    measured.finish()?;
    println!(
        "\nmeasured 1-thread speedup (paper claims 2.6x): {:.2}x",
        w1_gemm / w1_scalar
    );

    // Modelled Fig. 3 curve: calibrated coherence model, anchored at the
    // paper's Broadwell 1T rates (our measured RATIO validates the gap;
    // absolute per-core speed of this vCPU differs from a 2.3 GHz BDW).
    let bdw = broadwell();
    let p = FigParams::default();
    let axis = fig3_thread_axis(&bdw);
    let (scalar_curve, gemm_curve) =
        fig3_series(&bdw, &p, 70_000.0, 182_000.0, &axis);
    let mut modelled = BenchTable::new(
        "fig3_modelled_bdw",
        &["threads", "original_wps", "ours_wps", "speedup"],
    );
    for (s, g) in scalar_curve.iter().zip(&gemm_curve) {
        modelled.row(vec![
            s.x.to_string(),
            si(s.words_per_sec),
            si(g.words_per_sec),
            format!("{:.2}x", g.words_per_sec / s.words_per_sec),
        ]);
    }
    modelled.finish()?;
    println!(
        "\npaper anchors: original 1.6M words/s @72T, ours 5.8M words/s @72T (3.6x)"
    );
    Ok(())
}
