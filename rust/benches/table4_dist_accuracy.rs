//! Table IV — predictive accuracy of distributed word2vec as the node
//! count grows (paper Sec. IV-C).
//!
//! Entirely REAL: N replicas with separate models train on corpus shards
//! with sub-model sync + node-scaled learning rates, and the merged model
//! is evaluated on the ground-truth sets.  The paper's claim under
//! reproduction: accuracy holds near the single-node baseline as N grows
//! (within ~1 point up to large N), and the lr-scaling trick is what
//! makes that possible (ablation row).

use pw2v::bench::{accuracy_workload, BenchTable};
use pw2v::config::TrainConfig;
use pw2v::dist::{train_distributed, DistConfig};
use pw2v::eval;
use pw2v::model::SharedModel;
use pw2v::train;

fn main() -> anyhow::Result<()> {
    let wl = accuracy_workload(301)?;
    let sim_set = eval::gen_similarity_set(&wl.latent, 300, 7);
    let ana_set = eval::gen_analogy_set(&wl.latent);

    let mut cfg = TrainConfig::default();
    cfg.dim = 100;
    cfg.epochs = 3;
    cfg.sample = 1e-3;
    cfg.lr = 0.05;

    // Single-node shared-memory baseline ("Original (N=1)" row).
    let model = SharedModel::init(wl.vocab.len(), cfg.dim, cfg.seed);
    let mut base_cfg = cfg.clone();
    base_cfg.backend = pw2v::config::Backend::Scalar;
    train::train(&base_cfg, &wl.corpus, &wl.vocab, &model)?;
    let sim0 = eval::eval_similarity(&sim_set, &wl.vocab, model.m_in());
    let ana0 = eval::eval_analogy(&ana_set, &wl.vocab, model.m_in());

    let mut table = BenchTable::new(
        "table4_dist_accuracy",
        &["config", "similarity", "analogy"],
    );
    table.row(vec![
        "original (N=1)".into(),
        format!("{:.1}", sim0.rho100),
        format!("{:.1}", ana0.accuracy100()),
    ]);

    for nodes in [1usize, 2, 4, 8] {
        let mut dist = DistConfig::for_nodes(nodes);
        dist.policy =
            pw2v::dist::SyncPolicy::submodel_for_vocab(wl.vocab.len());
        // Interval scaled to this corpus (paper scale / ~1000) and
        // LINEARLY with N — the paper's Sec. IV-C "further increase model
        // synchronization frequency" at high node counts (the ablation
        // bench shows what happens without it).
        dist.sync_interval = (120_000 / nodes as u64).max(10_000);
        let out = train_distributed(&cfg, &dist, &wl.corpus, &wl.vocab)?;
        let sim = eval::eval_similarity(&sim_set, &wl.vocab, out.model.m_in());
        let ana = eval::eval_analogy(&ana_set, &wl.vocab, out.model.m_in());
        table.row(vec![
            format!("distributed N={nodes}"),
            format!("{:.1}", sim.rho100),
            format!("{:.1}", ana.accuracy100()),
        ]);
    }

    // Ablation: N=4 WITHOUT the paper's lr scaling.
    {
        let mut dist = DistConfig::for_nodes(4);
        dist.policy =
            pw2v::dist::SyncPolicy::submodel_for_vocab(wl.vocab.len());
        dist.sync_interval = 60_000;
        dist.scale_lr = false;
        let out = train_distributed(&cfg, &dist, &wl.corpus, &wl.vocab)?;
        let sim = eval::eval_similarity(&sim_set, &wl.vocab, out.model.m_in());
        let ana = eval::eval_analogy(&ana_set, &wl.vocab, out.model.m_in());
        table.row(vec![
            "N=4 without lr scaling (ablation)".into(),
            format!("{:.1}", sim.rho100),
            format!("{:.1}", ana.accuracy100()),
        ]);
    }

    table.finish()?;
    println!(
        "\npaper claim under reproduction: distributed accuracy within ~1-2\n\
         points of single-node out to large N (paper Table IV: 64.1 -> 63.2\n\
         similarity from N=1 to N=32 on BDW)"
    );
    Ok(())
}
