//! Table II — robustness of predictive accuracy as the vocabulary shrinks
//! (paper Sec. IV-B): smaller vocabularies concentrate updates on fewer
//! rows, raising Hogwild conflict rates; the claim is that BOTH schemes
//! hold their accuracy all the way down to the smallest vocabulary.
//!
//! REAL end-to-end: one corpus, vocabulary truncated to the top-N words,
//! both back-ends trained and evaluated per truncation.

use pw2v::bench::{accuracy_workload, BenchTable};
use pw2v::config::{Backend, TrainConfig};
use pw2v::eval;
use pw2v::model::SharedModel;
use pw2v::train;

fn main() -> anyhow::Result<()> {
    let wl = accuracy_workload(201)?;
    let full = wl.vocab.len();
    // The paper sweeps 1.1M -> 50K (×22); we sweep the same ×22 span.
    let sizes = vec![full, full / 2, full / 4, full / 10, full / 22];

    let mut table = BenchTable::new(
        "table2_vocab_sweep",
        &[
            "vocab_size",
            "sim_original",
            "sim_ours",
            "ana_original",
            "ana_ours",
            "sim_pairs_covered",
        ],
    );
    let sim_set = eval::gen_similarity_set(&wl.latent, 300, 7);
    let ana_set = eval::gen_analogy_set(&wl.latent);

    for &n in &sizes {
        let vocab = wl.vocab.truncated(n);
        eprintln!("vocab {n} ...");
        let mut row = vec![n.to_string()];
        let mut sims = Vec::new();
        let mut anas = Vec::new();
        let mut covered = 0usize;
        for backend in [Backend::Scalar, Backend::Gemm] {
            let mut cfg = TrainConfig::default();
            cfg.backend = backend;
            cfg.dim = 100;
            cfg.epochs = 3;
            cfg.sample = 1e-3;
            cfg.lr = 0.05;
            let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
            train::train(&cfg, &wl.corpus, &vocab, &model)?;
            let sim = eval::eval_similarity(&sim_set, &vocab, model.m_in());
            let ana = eval::eval_analogy(&ana_set, &vocab, model.m_in());
            covered = sim.pairs_covered;
            sims.push(sim.rho100);
            anas.push(ana.accuracy100());
        }
        row.push(format!("{:.1}", sims[0]));
        row.push(format!("{:.1}", sims[1]));
        row.push(format!("{:.1}", anas[0]));
        row.push(format!("{:.1}", anas[1]));
        // Coverage context: test pairs are drawn over the FULL vocabulary,
        // so tiny truncations evaluate on very few pairs (the paper's
        // smallest vocab is 4.5% of full — same ratio as our last row).
        row.push(covered.to_string());
        table.row(row);
    }
    table.finish()?;
    println!(
        "\npaper claim under reproduction: ours tracks the original at every\n\
         vocabulary size, including the smallest (paper Table II)"
    );
    Ok(())
}
