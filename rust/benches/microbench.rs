//! Microbenchmarks of the hot-path primitives (the §Perf working set):
//! GEMM kernels at the paper's shapes, the fused-vs-gemm3 window-kernel
//! ablation, level-1 ops, negative-sampler implementations, and the PJRT
//! per-call overhead that motivates superbatching.
//!
//! `cargo bench --bench microbench -- --json` additionally merges the
//! kernel GFLOP/s and the fused ablation into `BENCH_throughput.json` at
//! the repo root (the machine-readable perf trajectory).

use pw2v::bench::{speedup, time, BenchTable, ThroughputReport};
use pw2v::corpus::encoded::EncodedCorpus;
use pw2v::corpus::reader::SentenceReader;
use pw2v::corpus::vocab::Vocab;
use pw2v::linalg::simd::{self, SimdMode};
use pw2v::linalg::{axpy, dot, gemm_nn, gemm_nt, gemm_tn};
use pw2v::model::ShardMap;
use pw2v::runtime::topology::Topology;
use pw2v::runtime::{Manifest, Runtime};
use pw2v::sampling::batch::{BatchBuilder, SuperbatchArena};
use pw2v::sampling::unigram::UnigramSampler;
use pw2v::train::route::{owner_head_k, Exchange, Outbox, RouteSink, RowRouter};
use pw2v::util::args::Args;
use pw2v::util::json::Json;
use pw2v::util::rng::Xoshiro256ss;
use pw2v::util::si;
use std::collections::{BTreeMap, HashMap};

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256ss::new(seed);
    (0..n).map(|_| r.next_f32() - 0.5).collect()
}

/// Dispatch levels this machine can run, widest first, scalar always
/// last (the reference row every speedup normalises against).  Missing
/// tiers are logged, not errors — the benches degrade per level.
fn available_levels(bench: &str) -> Vec<SimdMode> {
    let mut levels = Vec::new();
    if simd::configure(SimdMode::Avx512).is_ok() {
        levels.push(SimdMode::Avx512);
    } else {
        eprintln!("{bench}: no avx512f+avx512bw, avx512 tier skipped");
    }
    if simd::configure(SimdMode::Avx2).is_ok() {
        levels.push(SimdMode::Avx2);
    } else {
        eprintln!("{bench}: no avx2+fma, avx2 tier skipped");
    }
    levels.push(SimdMode::Scalar);
    simd::configure(SimdMode::Auto).expect("auto never fails");
    levels
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env_tail(1);
    let mut report = args.flag("json").then(ThroughputReport::open_at_repo_root);
    simd_dispatch_bench(&mut report)?;
    sgns_window_ablation(&mut report)?;
    numa_row_update_bench(&mut report)?;
    routing_bench(&mut report)?;
    dist_ring_bench(&mut report)?;
    corpus_cache_bench(&mut report)?;
    serve_scan_bench(&mut report)?;
    gemm_bench()?;
    vecops_bench()?;
    sampler_bench()?;
    pjrt_call_overhead()?;
    if let Some(r) = report.as_mut() {
        r.save()?;
    }
    Ok(())
}

/// The tentpole ablation: one window at the paper's (B=16, S=6, D=300)
/// shape, the fused single-pass kernel vs the gemm3 chain — each EXACTLY
/// as the arena path runs it (fused reads `Wo` / accumulates `dWo`
/// through the superbatch dedup slots; gemm3 assembles the window block,
/// runs the 3 GEMMs + error kernel, then axpy-accumulates `dWo` per
/// slot).  One window = one center word, so windows/sec is the
/// kernel-level words/sec bound the acceptance criterion tracks
/// (floor: fused ≥ 1.3× gemm3 single-thread).
fn sgns_window_ablation(
    report: &mut Option<ThroughputReport>,
) -> anyhow::Result<()> {
    let (b, s, d) = (16usize, 6usize, 300usize);
    let u = 64usize; // distinct output rows in the dedup block
    let wi = randv(b * d, 21);
    let wo_uniq = randv(u * d, 22);
    let slots: Vec<u32> = vec![3, 17, 9, 33, 41, 58];
    let lr = 0.025f32;
    // FMA count of the mathematical window: logits + dWi + dWo.
    let flops = 3.0 * 2.0 * (b * s * d) as f64;

    let mut wo_blk = vec![0.0f32; s * d];
    let mut logits = vec![0.0f32; b * s];
    let mut dwi = vec![0.0f32; b * d];
    let mut dwo_blk = vec![0.0f32; s * d];
    let mut dwo_uniq = vec![0.0f32; u * d];

    let mut table = BenchTable::new(
        "micro_sgns_window",
        &["level", "kernel", "ns_per_window", "gflops", "windows_per_sec"],
    );
    let levels = available_levels("micro_sgns_window");
    let mut json_levels: BTreeMap<String, Json> = BTreeMap::new();
    for &mode in &levels {
        let level = simd::configure(mode)?;
        dwo_uniq.fill(0.0);
        let st3 = time(100, 2000, || {
            for (j, &slot) in slots.iter().enumerate() {
                let r = slot as usize * d;
                wo_blk[j * d..(j + 1) * d]
                    .copy_from_slice(&wo_uniq[r..r + d]);
            }
            simd::gemm_nt(b, s, d, 1.0, &wi, &wo_blk, 0.0, &mut logits);
            simd::sgns_err(&mut logits, s, lr);
            simd::gemm_nn(b, d, s, 1.0, &logits, &wo_blk, 0.0, &mut dwi);
            simd::gemm_tn(s, d, b, 1.0, &logits, &wi, 0.0, &mut dwo_blk);
            for (j, &slot) in slots.iter().enumerate() {
                let r = slot as usize * d;
                simd::axpy(
                    1.0,
                    &dwo_blk[j * d..(j + 1) * d],
                    &mut dwo_uniq[r..r + d],
                );
            }
            std::hint::black_box(&dwo_uniq);
        });
        dwo_uniq.fill(0.0);
        let stf = time(100, 2000, || {
            simd::sgns_fused(
                s,
                d,
                lr,
                &wi,
                &wo_uniq,
                &slots,
                &mut logits,
                &mut dwi,
                &mut dwo_uniq,
            );
            std::hint::black_box(&dwo_uniq);
        });
        let ratio = speedup(&stf, &st3); // >1: fused wins
        let mut row = |kernel: &str, st: &pw2v::bench::Stats| {
            table.row(vec![
                level.to_string(),
                kernel.into(),
                format!("{:.0}", st.median * 1e9),
                format!("{:.2}", flops / st.median / 1e9),
                si(1.0 / st.median),
            ]);
        };
        row("fused", &stf);
        row("gemm3", &st3);
        println!(
            "sgns window @({b},{s},{d}) [{level}]: fused {ratio:.2}x over \
             gemm3 (acceptance floor 1.3x single-thread)"
        );

        // Cross-window reuse ablation (`--reuse sentence`): a run of R=8
        // windows sharing one negative set — 8 sequential per-window
        // fused calls (the `--reuse off` traffic pattern, Wo rows
        // re-read per window) vs ONE `sgns_fused_run` (negative rows +
        // dWo accumulators carried across the run).  Same math bitwise;
        // the ratio is pure memory-traffic win.
        let r_n = 8usize;
        let wi_run = randv(r_n * b * d, 23);
        let offs: Vec<u32> = (0..=r_n as u32).map(|w| w * b as u32).collect();
        let positives: [u32; 8] = [3, 1, 5, 7, 11, 13, 21, 27];
        let mut slots_run = Vec::with_capacity(r_n * s);
        for w in 0..r_n {
            slots_run.push(positives[w]);
            slots_run.extend_from_slice(&slots[1..]);
        }
        let mut err_run = vec![0.0f32; r_n * b * s];
        let mut dwi_run = vec![0.0f32; r_n * b * d];
        dwo_uniq.fill(0.0);
        let st_seq = time(50, 500, || {
            for w in 0..r_n {
                let (lo, hi) = (w * b, (w + 1) * b);
                simd::sgns_fused(
                    s,
                    d,
                    lr,
                    &wi_run[lo * d..hi * d],
                    &wo_uniq,
                    &slots_run[w * s..(w + 1) * s],
                    &mut err_run[lo * s..hi * s],
                    &mut dwi_run[lo * d..hi * d],
                    &mut dwo_uniq,
                );
            }
            std::hint::black_box(&dwo_uniq);
        });
        dwo_uniq.fill(0.0);
        let st_run = time(50, 500, || {
            simd::sgns_fused_run(
                s,
                d,
                lr,
                &wi_run,
                &offs,
                &wo_uniq,
                &slots_run,
                &mut err_run,
                &mut dwi_run,
                &mut dwo_uniq,
            );
            std::hint::black_box(&dwo_uniq);
        });
        let reuse_ratio = speedup(&st_run, &st_seq); // >1: run kernel wins
        let mut run_row = |kernel: &str, st: &pw2v::bench::Stats| {
            table.row(vec![
                level.to_string(),
                kernel.into(),
                format!("{:.0}", st.median / r_n as f64 * 1e9),
                format!("{:.2}", flops * r_n as f64 / st.median / 1e9),
                si(r_n as f64 / st.median),
            ]);
        };
        run_row("fused_seq_r8", &st_seq);
        run_row("fused_run_r8", &st_run);
        println!(
            "sgns reuse run @R={r_n} [{level}]: run kernel {reuse_ratio:.2}x \
             over sequential fused (cross-window negative reuse)"
        );

        let per_kernel = |st: &pw2v::bench::Stats| {
            Json::obj([
                ("ns_per_window", Json::num(st.median * 1e9)),
                ("gflops", Json::num(flops / st.median / 1e9)),
                ("words_per_sec", Json::num(1.0 / st.median)),
            ])
        };
        let per_run_window = |st: &pw2v::bench::Stats| {
            let per_window = st.median / r_n as f64;
            Json::obj([
                ("ns_per_window", Json::num(per_window * 1e9)),
                ("gflops", Json::num(flops / per_window / 1e9)),
                ("words_per_sec", Json::num(1.0 / per_window)),
            ])
        };
        json_levels.insert(
            level.to_string(),
            Json::obj([
                ("fused", per_kernel(&stf)),
                ("gemm3", per_kernel(&st3)),
                ("fused_over_gemm3", Json::num(ratio)),
                ("fused_seq_r8", per_run_window(&st_seq)),
                ("fused_run_r8", per_run_window(&st_run)),
                ("fused_reuse_over_off", Json::num(reuse_ratio)),
            ]),
        );
    }
    simd::configure(SimdMode::Auto)?;
    table.finish()?;
    if let Some(r) = report.as_mut() {
        r.set(
            "micro_sgns_window",
            Json::obj([
                (
                    "shape",
                    Json::obj([
                        ("b", Json::Num(b as f64)),
                        ("s", Json::Num(s as f64)),
                        ("d", Json::Num(d as f64)),
                        ("uniq_rows", Json::Num(u as f64)),
                        ("run_windows", Json::Num(8.0)),
                    ]),
                ),
                ("levels", Json::Obj(json_levels)),
            ]),
        );
    }
    Ok(())
}

/// Dispatch-aware kernel rows (`dot/avx2`, `gemm_nt/scalar`, …): the
/// SIMD-vs-scalar contrast this crate's perf trajectory tracks from the
/// explicit-SIMD PR onward.  Record the output in EXPERIMENTS.md §Perf;
/// `--json` lands the same numbers in `BENCH_throughput.json`.
fn simd_dispatch_bench(
    report: &mut Option<ThroughputReport>,
) -> anyhow::Result<()> {
    let mut table = BenchTable::new(
        "micro_simd_dispatch",
        &["kernel", "level", "shape", "ns_per_call", "gflops"],
    );
    let mut json_levels: BTreeMap<String, Json> = BTreeMap::new();
    // The paper's window shapes: B=16, S=6, D=300.
    let (b, s, d) = (16usize, 6usize, 300usize);
    let wi = randv(b * d, 1);
    let wo = randv(s * d, 2);
    let err = randv(b * s, 3);
    let va = randv(d, 4);
    let mut vy = randv(d, 5);
    let mut out_bs = vec![0.0f32; b * s];
    let mut out_bd = vec![0.0f32; b * d];
    let mut out_sd = vec![0.0f32; s * d];
    let gemm_flops = 2.0 * b as f64 * s as f64 * d as f64;
    let iters = 2000;

    let mut speedups: Vec<(String, f64)> = Vec::new();
    let levels = available_levels("micro_simd_dispatch");
    let mut per_kernel: HashMap<&'static str, Vec<(String, pw2v::bench::Stats)>> =
        HashMap::new();
    for &mode in &levels {
        let level = simd::configure(mode)?;
        let mut level_json: BTreeMap<String, Json> = BTreeMap::new();
        let mut entry = |name: &'static str, st: pw2v::bench::Stats, flops: f64| {
            per_kernel
                .entry(name)
                .or_default()
                .push((level.to_string(), st));
            level_json.insert(
                name.to_string(),
                Json::obj([
                    ("ns_per_call", Json::num(st.median * 1e9)),
                    (
                        "gflops",
                        if flops > 0.0 {
                            Json::num(flops / st.median / 1e9)
                        } else {
                            Json::Null
                        },
                    ),
                ]),
            );
            table.row(vec![
                name.into(),
                level.to_string(),
                if flops > 0.0 {
                    format!("[{b},{d}]x[{d},{s}]")
                } else {
                    format!("d={d}")
                },
                format!("{:.0}", st.median * 1e9),
                if flops > 0.0 {
                    format!("{:.2}", flops / st.median / 1e9)
                } else {
                    "-".into()
                },
            ]);
        };

        let st = time(200, 20_000, || {
            std::hint::black_box(simd::dot(&wi[..d], &wo[..d]));
        });
        entry("dot", st, 0.0);
        let st = time(200, 20_000, || {
            simd::axpy(0.01, &va, &mut vy);
            std::hint::black_box(&vy);
        });
        entry("axpy", st, 0.0);
        let st = time(100, iters, || {
            simd::gemm_nt(b, s, d, 1.0, &wi, &wo, 0.0, &mut out_bs);
            std::hint::black_box(&out_bs);
        });
        entry("gemm_nt", st, gemm_flops);
        let st = time(100, iters, || {
            simd::gemm_nn(b, d, s, 1.0, &err, &wo, 0.0, &mut out_bd);
            std::hint::black_box(&out_bd);
        });
        entry("gemm_nn", st, gemm_flops);
        let st = time(100, iters, || {
            simd::gemm_tn(s, d, b, 1.0, &err, &wi, 0.0, &mut out_sd);
            std::hint::black_box(&out_sd);
        });
        entry("gemm_tn", st, gemm_flops);
        let st = time(100, iters, || {
            let mut e = err.clone();
            simd::sgns_err(&mut e, s, 0.025);
            std::hint::black_box(&e);
        });
        entry("sgns_err", st, 0.0);
        json_levels.insert(level.to_string(), Json::Obj(level_json));
    }
    simd::configure(SimdMode::Auto)?;
    table.finish()?;
    if let Some(r) = report.as_mut() {
        r.set("micro_kernels", Json::Obj(json_levels));
    }

    if levels.len() > 1 {
        // Pair every vector tier against the scalar reference BY NAME —
        // never by index, so the table stays correct whichever subset of
        // {avx512, avx2} this machine has.
        let mut table = BenchTable::new(
            "micro_simd_speedup",
            &["kernel", "level", "over_scalar"],
        );
        for name in ["dot", "axpy", "gemm_nt", "gemm_nn", "gemm_tn", "sgns_err"] {
            let runs = &per_kernel[name];
            let scalar = runs
                .iter()
                .find(|(l, _)| l == "scalar")
                .expect("scalar tier always runs");
            for (lvl, st) in runs {
                if lvl == "scalar" {
                    continue;
                }
                let ratio = pw2v::bench::speedup(st, &scalar.1);
                speedups.push((format!("{name}/{lvl}"), ratio));
                table.row(vec![
                    name.into(),
                    lvl.clone(),
                    format!("{ratio:.2}x"),
                ]);
            }
        }
        table.finish()?;
        if let Some((_, r)) =
            speedups.iter().find(|(n, _)| n == "gemm_nt/avx2")
        {
            println!(
                "gemm_nt avx2 speedup at (16,6,300): {r:.2}x \
                 (acceptance floor: 1.5x)"
            );
        }
    }
    Ok(())
}

/// Ingest-layer contrast on the standard 2M-token workload: the one-time
/// encode cost (MB/s of source text) vs the per-epoch read cost of the
/// streaming text path (tokenize + hash every token) and the encoded
/// `u32` cache (sequential id scan, zero hashing).  `--json` lands all
/// three in `BENCH_throughput.json` — the cached/text read ratio is the
/// epoch-2+ ingest speedup the corpus-cache PR claims.
fn corpus_cache_bench(
    report: &mut Option<ThroughputReport>,
) -> anyhow::Result<()> {
    let wl = pw2v::bench::standard_workload()?;
    let cache = std::env::temp_dir().join(format!(
        "pw2v_micro_cache_{}.pw2v.u32",
        std::process::id()
    ));
    let mut stats = None;
    let st_encode = time(0, 3, || {
        stats = Some(EncodedCorpus::build(&wl.corpus, &wl.vocab, &cache).unwrap());
    });
    let stats = stats.expect("at least one encode iteration ran");
    let enc = EncodedCorpus::open(&cache, &wl.vocab)?;

    let mut sent: Vec<u32> = Vec::new();
    let mut tokens = 0u64;
    let st_cached = time(1, 5, || {
        tokens = 0;
        let mut r = enc.reader();
        while r.next_sentence_into(&mut sent).unwrap() {
            tokens += sent.len() as u64;
        }
        std::hint::black_box(tokens);
    });
    let st_text = time(1, 5, || {
        let mut n = 0u64;
        let mut r = SentenceReader::open(&wl.corpus, &wl.vocab).unwrap();
        while r.next_sentence_into(&mut sent).unwrap() {
            n += sent.len() as u64;
        }
        std::hint::black_box(n);
    });

    let encode_mbs = stats.text_bytes as f64 / 1e6 / st_encode.median;
    let text_wps = tokens as f64 / st_text.median;
    let cached_wps = tokens as f64 / st_cached.median;
    let ratio = speedup(&st_cached, &st_text); // >1: cached read wins

    let mut table = BenchTable::new(
        "micro_corpus_cache",
        &["stage", "metric", "value"],
    );
    table.row(vec![
        "encode (one-time)".into(),
        "MB/s of text".into(),
        format!("{encode_mbs:.0}"),
    ]);
    table.row(vec![
        "read text (per epoch)".into(),
        "words/sec".into(),
        si(text_wps),
    ]);
    table.row(vec![
        "read cached (per epoch)".into(),
        "words/sec".into(),
        si(cached_wps),
    ]);
    table.row(vec![
        "cached/text".into(),
        "ratio".into(),
        format!("{ratio:.2}x"),
    ]);
    table.finish()?;
    println!(
        "corpus cache: encode {encode_mbs:.0} MB/s once, then epoch reads \
         {ratio:.2}x faster than streaming text"
    );
    if let Some(r) = report.as_mut() {
        r.set(
            "micro_corpus_cache",
            Json::obj([
                ("text_bytes", Json::num(stats.text_bytes as f64)),
                ("sentences", Json::num(stats.sentences as f64)),
                ("tokens", Json::num(stats.tokens as f64)),
                ("encode_mb_per_sec", Json::num(encode_mbs)),
                ("text_read_words_per_sec", Json::num(text_wps)),
                ("cached_read_words_per_sec", Json::num(cached_wps)),
                ("cached_over_text", Json::num(ratio)),
            ]),
        );
    }
    std::fs::remove_file(&cache).ok();
    Ok(())
}

/// NUMA contrast for the Hogwild scatter pattern: row-sized `axpy`
/// updates (`y += alpha·x`, D=300 — exactly a model-row scatter) swept
/// over a working set first-touched on EACH node, driven from a thread
/// pinned to node 0.  On a multi-socket box the remote-buffer sweep pays
/// interconnect latency/bandwidth; the local/remote ratio is the
/// per-row cost the `--numa` sharding avoids.  Single-node machines (and
/// `PW2V_TOPOLOGY` overrides) report the local number only.
fn numa_row_update_bench(
    report: &mut Option<ThroughputReport>,
) -> anyhow::Result<()> {
    let topo = Topology::detect()?;
    let nodes = topo.nodes();
    let d = 300usize;
    // ~157 MB per buffer — well past mainstream server LLCs (55–60 MB
    // on the dual-socket BDW/ICX class this bench targets), so sweeps
    // stream from the buffer's HOME memory rather than cache and the
    // local/remote ratio measures the interconnect, not the LLC.
    // (Exotic V-cache parts with >157 MB LLC would still cache it —
    // the `nodes`/`rows` fields in the JSON record the geometry.)
    let rows = 131_072usize;
    // One buffer per node: allocated (untouched zero pages) and first
    // WRITTEN inside a thread pinned to that node.
    let mut bufs: Vec<(bool, Vec<f32>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..nodes)
            .map(|node| {
                let topo = &topo;
                s.spawn(move || {
                    let pinned = topo.pin_to_node(node);
                    // The allocation maps untouched zero pages; this
                    // fill is the first touch, from the pinned thread.
                    let mut v = vec![0.0f32; rows * d];
                    v.fill(0.25);
                    (pinned, v)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let pinned_all = bufs.iter().all(|(p, _)| *p);
    let delta = vec![0.01f32; d];
    // Measure from node 0's perspective: sweep every buffer with
    // row-granularity scatter-adds.
    let stats: Vec<pw2v::bench::Stats> = std::thread::scope(|s| {
        s.spawn(|| {
            topo.pin_to_node(0);
            bufs.iter_mut()
                .map(|(_, buf)| {
                    time(1, 5, || {
                        for r in 0..rows {
                            simd::axpy(
                                1.0,
                                &delta,
                                &mut buf[r * d..(r + 1) * d],
                            );
                        }
                        std::hint::black_box(&buf);
                    })
                })
                .collect()
        })
        .join()
        .unwrap()
    });

    let gbps =
        |st: &pw2v::bench::Stats| 2.0 * (rows * d * 4) as f64 / st.median / 1e9;
    let mut table = BenchTable::new(
        "micro_numa",
        &["buffer_home_node", "gb_per_sec", "vs_node0"],
    );
    let local = gbps(&stats[0]);
    let mut per_node = Vec::new();
    for (node, st) in stats.iter().enumerate() {
        let g = gbps(st);
        per_node.push(Json::num(g));
        table.row(vec![
            node.to_string(),
            format!("{g:.1}"),
            format!("{:.2}x", local / g.max(1e-9)),
        ]);
    }
    table.finish()?;
    if !pinned_all {
        eprintln!(
            "micro_numa: pinning unavailable on this host — numbers do not \
             separate local from remote"
        );
    }
    let remote = (nodes > 1).then(|| gbps(&stats[nodes - 1]));
    match remote {
        Some(r) => println!(
            "numa row-update bandwidth from node 0: local {local:.1} GB/s, \
             remote {r:.1} GB/s ({:.2}x)",
            local / r.max(1e-9)
        ),
        None => println!(
            "numa row-update bandwidth: {local:.1} GB/s (single node — no \
             remote leg)"
        ),
    }
    if let Some(rep) = report.as_mut() {
        rep.set(
            "micro_numa",
            Json::obj([
                ("nodes", Json::num(nodes as f64)),
                ("pinned", Json::Bool(pinned_all)),
                ("dim", Json::num(d as f64)),
                ("rows", Json::num(rows as f64)),
                ("per_node_gb_per_sec", Json::Arr(per_node)),
                ("local_gb_per_sec", Json::num(local)),
                (
                    "remote_gb_per_sec",
                    remote.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "local_over_remote",
                    remote
                        .map(|r| Json::num(local / r.max(1e-9)))
                        .unwrap_or(Json::Null),
                ),
            ]),
        );
    }
    Ok(())
}

/// Ownership-routing layer costs and coverage on a one-node box (the
/// cross-socket WIN needs a multi-socket runner — `fig3_route` tracks
/// that; what is machine-robust HERE):
///
/// * `classify_ns_per_id` — the per-window router decision (head cutoff
///   + shard-map home lookup) on a realistic Zipf id stream;
/// * `routed_over_unrouted` — window-generation pipeline throughput with
///   the full exchange in the loop (RouteSink classification, mailbox
///   block push/pop, consumer `append_from` adoption) relative to the
///   plain `fill_arena`, single thread.  This is the routing OVERHEAD
///   bound (≤1 by construction here): the relative metric the trend
///   gate watches so the exchange never silently becomes expensive;
/// * analytic remote OUTPUT-row shares at B=16/S=6 under a two-node
///   map: `--numa` alone vs `--route owner` (upper bound — ignores
///   backpressure fallback), the locality headroom the routed head buys.
fn routing_bench(report: &mut Option<ThroughputReport>) -> anyhow::Result<()> {
    let v = 100_000usize;
    let counts: HashMap<String, u64> = (0..v)
        .map(|i| (format!("w{i}"), (1_000_000_000 / (i + 1)) as u64))
        .collect();
    let vocab = Vocab::from_counts(counts, 1);
    let nodes = 2usize;
    let head_k = owner_head_k(&vocab);
    let router = RowRouter::new(ShardMap::contiguous(v, nodes), head_k);
    let sampler = UnigramSampler::alias(&vocab, 0.75);
    let mut rng = Xoshiro256ss::new(41);
    let ids: Vec<u32> = (0..1_000_000).map(|_| sampler.sample(&mut rng)).collect();

    // 1) Router classification throughput.
    let st_classify = time(3, 20, || {
        let mut acc = 0usize;
        for &id in &ids {
            if let Some(node) = router.route(id) {
                acc += node;
            }
        }
        std::hint::black_box(acc);
    });
    let classify_ns = st_classify.median * 1e9 / ids.len() as f64;

    // 2) Analytic remote share of output-row accesses (S=6: target + 5
    // shared negatives), windows generated alternately on each node.
    let s = 6usize;
    let windows = ids.len() / s;
    let (mut remote_off, mut remote_owner) = (0u64, 0u64);
    for (w, outs) in ids.chunks_exact(s).enumerate() {
        let gen_node = w % nodes;
        let proc_node = router.route(outs[0]).unwrap_or(gen_node);
        for &id in outs {
            let home = router.home_node(id);
            if home != gen_node {
                remote_off += 1;
            }
            if home != proc_node {
                remote_owner += 1;
            }
        }
    }
    let total_rows = (windows * s) as f64;
    let share_off = remote_off as f64 / total_rows;
    let share_owner = remote_owner as f64 / total_rows;

    // 3) Exchange overhead: the generation pipeline end to end, plain
    // vs routed (both sides of a two-worker exchange driven by this one
    // thread; no backend processing — isolates the routing machinery).
    let (window, batch, negative, superbatch) = (5usize, 16usize, 5usize, 64);
    let mut builder = BatchBuilder::new(&sampler, window, batch, negative);
    let sentences: Vec<Vec<u32>> = (0..64)
        .map(|i| {
            let mut r = Xoshiro256ss::new(1000 + i);
            (0..60).map(|_| sampler.sample(&mut r)).collect()
        })
        .collect();
    // Every position of a multi-token sentence is a center → one window.
    let n_windows: usize = sentences.iter().map(|sent| sent.len()).sum();
    let mut plain = SuperbatchArena::with_sentence_slack(superbatch, batch, 1 + negative);
    let st_plain = time(10, 200, || {
        let mut r = Xoshiro256ss::new(7);
        for sent in &sentences {
            builder.fill_arena(sent, &mut r, &mut plain);
            if plain.len() >= superbatch {
                plain.clear();
            }
        }
        plain.clear();
        std::hint::black_box(&plain);
    });
    let exch = Exchange::new(2, 2, 64, batch, 1 + negative);
    let mut a0 = SuperbatchArena::with_route_slack(
        superbatch,
        batch,
        1 + negative,
        exch.max_inflight(),
    );
    let mut a1 = SuperbatchArena::with_route_slack(
        superbatch,
        batch,
        1 + negative,
        exch.max_inflight(),
    );
    let mut outbox = Outbox::new(&exch, &router, 0);
    let st_routed = time(10, 200, || {
        let mut r = Xoshiro256ss::new(7);
        for sent in &sentences {
            {
                let mut sink = RouteSink::new(&mut a0, &mut outbox);
                builder.fill_arena_routed(sent, &mut r, &mut sink);
            }
            exch.drain_into(1, &mut a1);
            if a0.len() >= superbatch {
                outbox.flush();
                a0.clear();
            }
            if a1.len() >= superbatch {
                a1.clear();
            }
        }
        outbox.flush();
        exch.drain_into(1, &mut a1);
        a0.clear();
        a1.clear();
        std::hint::black_box(&a1);
    });
    let ratio = speedup(&st_routed, &st_plain); // <1: routing overhead
    let routed_wps = n_windows as f64 / st_routed.median;

    let mut table = BenchTable::new("micro_routing", &["metric", "value"]);
    table.row(vec![
        "owner head K (90% mass)".into(),
        format!("{head_k} of {v}"),
    ]);
    table.row(vec!["classify ns/id".into(), format!("{classify_ns:.1}")]);
    table.row(vec![
        "remote out-row share, numa alone".into(),
        format!("{share_off:.3}"),
    ]);
    table.row(vec![
        "remote out-row share, route owner".into(),
        format!("{share_owner:.3}"),
    ]);
    table.row(vec![
        "routed pipeline windows/sec".into(),
        si(routed_wps),
    ]);
    table.row(vec![
        "routed/unrouted generation".into(),
        format!("{ratio:.2}x"),
    ]);
    table.finish()?;
    println!(
        "routing: head {head_k}/{v} cuts analytic remote out-row share \
         {share_off:.3} -> {share_owner:.3}; exchange overhead {ratio:.2}x"
    );
    if let Some(r) = report.as_mut() {
        r.set(
            "micro_routing",
            Json::obj([
                ("vocab", Json::num(v as f64)),
                ("nodes", Json::num(nodes as f64)),
                ("head_k", Json::num(head_k as f64)),
                ("classify_ns_per_id", Json::num(classify_ns)),
                ("remote_share_off", Json::num(share_off)),
                ("remote_share_owner", Json::num(share_owner)),
                ("routed_windows_per_sec", Json::num(routed_wps)),
                ("routed_over_unrouted", Json::num(ratio)),
            ]),
        );
    }
    Ok(())
}

/// The TCP allreduce collective on a 3-rank loopback ring: per-round
/// latency and slice throughput for a realistic sub-model due set, plus
/// the wire-byte CONTRACT — measured `slice_bytes_sent` must equal the
/// frame-level predictor `gather_scatter_wire_bytes` exactly (the trend
/// gate pins `measured_over_predicted_bytes` to 1.0; MB/s itself is
/// machine-dependent and warn-only).
fn dist_ring_bench(report: &mut Option<ThroughputReport>) -> anyhow::Result<()> {
    use pw2v::dist::net::{gather_scatter_wire_bytes, NetConfig, NetStats, Ring};
    use pw2v::dist::RingSpec;
    use pw2v::model::SharedModel;
    use std::net::TcpListener;
    use std::time::Instant;

    let n = 3usize;
    let (vocab, dim) = (50_000usize, 300usize);
    // ~19.7 MB payload per round: the hot head of a 50k vocab.
    let due = vec![0u32..8192u32];
    let rounds = 5u32;

    let listeners: Vec<TcpListener> = (0..n)
        .map(|_| TcpListener::bind("127.0.0.1:0"))
        .collect::<std::io::Result<_>>()?;
    let addrs: Vec<String> = listeners
        .iter()
        .map(|l| Ok(format!("127.0.0.1:{}", l.local_addr()?.port())))
        .collect::<std::io::Result<_>>()?;
    let net = NetConfig {
        connect_timeout_ms: 10_000,
        io_timeout_ms: 30_000,
        heartbeat_ms: 200,
        rejoin_grace_ms: 0,
    };

    let outs: Vec<(f64, NetStats)> = std::thread::scope(|s| {
        let handles: Vec<_> = listeners
            .into_iter()
            .enumerate()
            .map(|(rank, l)| {
                let addrs = addrs.clone();
                let due = due.clone();
                s.spawn(move || -> anyhow::Result<(f64, NetStats)> {
                    let spec = RingSpec { rank, addrs };
                    let model = SharedModel::init(vocab, dim, 11);
                    let mut ring = Ring::establish_on(l, &spec, &net, 0)?;
                    let t0 = Instant::now();
                    for r in 1..=rounds {
                        ring.allreduce_rows(&model, &due, r)?;
                    }
                    Ok((t0.elapsed().as_secs_f64(), ring.stats()))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("bench rank panicked"))
            .collect::<anyhow::Result<Vec<_>>>()
    })?;

    let (secs, stats) = outs[0];
    let predicted = rounds as u64 * gather_scatter_wire_bytes(&due, n, 0, dim);
    let measured_over_predicted = stats.slice_bytes_sent as f64 / predicted as f64;
    let round_ms = secs / rounds as f64 * 1e3;
    let mb_per_sec = stats.slice_bytes_sent as f64 / 1e6 / secs;

    let mut table = BenchTable::new("micro_dist_ring", &["metric", "value"]);
    table.row(vec!["ranks".into(), n.to_string()]);
    table.row(vec![
        "due rows x dim".into(),
        format!("{} x {dim}", due.iter().map(|r| r.len()).sum::<usize>()),
    ]);
    table.row(vec!["round ms (rank 0)".into(), format!("{round_ms:.1}")]);
    table.row(vec!["slice MB/s (rank 0)".into(), format!("{mb_per_sec:.0}")]);
    table.row(vec![
        "measured/predicted bytes".into(),
        format!("{measured_over_predicted:.6}"),
    ]);
    table.finish()?;
    println!(
        "dist ring: {n} loopback ranks, {round_ms:.1} ms/round at {} slice \
         MB/s; wire bytes measured/predicted = {measured_over_predicted:.6} \
         (contract: exactly 1)",
        mb_per_sec as u64
    );
    if let Some(r) = report.as_mut() {
        r.set(
            "micro_dist_ring",
            Json::obj([
                ("nranks", Json::num(n as f64)),
                ("dim", Json::num(dim as f64)),
                (
                    "due_rows",
                    Json::num(due.iter().map(|r| r.len()).sum::<usize>() as f64),
                ),
                ("rounds", Json::num(rounds as f64)),
                ("round_ms", Json::num(round_ms)),
                ("slice_mb_per_sec", Json::num(mb_per_sec)),
                (
                    "measured_over_predicted_bytes",
                    Json::num(measured_over_predicted),
                ),
            ]),
        );
    }
    Ok(())
}

/// Serve-scan throughput: queries/sec of the f32 unit-row scan vs the
/// int8 quantized scan (V=5000, D=128 — a scan-bandwidth-bound shape;
/// the bandwidth accounting lives in EXPERIMENTS.md §Serving).  `--json`
/// lands both rates and the int8/f32 ratio in `BENCH_throughput.json`;
/// the trend rows are warn-only (absolute q/s is machine-dependent, and
/// the int8 WIN only materialises once the store outgrows the LLC).
fn serve_scan_bench(report: &mut Option<ThroughputReport>) -> anyhow::Result<()> {
    use pw2v::config::QuantMode;
    use pw2v::model::Embedding;
    use pw2v::serve::{RowStore, Scratch, ServeEngine};

    let (v, d) = (5000usize, 128usize);
    let mut emb = Embedding::zeros(v, d);
    let mut rng = Xoshiro256ss::new(88);
    for id in 0..v as u32 {
        for x in emb.row_mut(id) {
            *x = rng.next_f32() - 0.5;
        }
    }
    let words: Vec<String> = (0..v).map(|i| format!("w{i:05}")).collect();
    let mut table = BenchTable::new(
        "micro_serve",
        &["scan", "ns_per_query", "queries_per_sec"],
    );
    let mut qps: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut scratch = Scratch::default();
    for (name, quant) in [("f32", QuantMode::Off), ("int8", QuantMode::Int8)] {
        let eng = ServeEngine::from_store(
            RowStore::from_model(words.clone(), &emb).unwrap(),
            quant,
        )
        .unwrap();
        let mut q = 0u32;
        let st = time(20, 200, || {
            std::hint::black_box(eng.topk(q % v as u32, 10, &mut scratch));
            q = q.wrapping_add(101);
        });
        table.row(vec![
            name.into(),
            format!("{:.0}", st.median * 1e9),
            si(1.0 / st.median),
        ]);
        qps.insert(name, 1.0 / st.median);
    }
    table.finish()?;
    let ratio = qps["int8"] / qps["f32"];
    println!(
        "serve scan V={v} D={d}: f32 {} q/s, int8 {} q/s ({ratio:.2}x)",
        si(qps["f32"]),
        si(qps["int8"])
    );
    if let Some(r) = report.as_mut() {
        r.set(
            "micro_serve",
            Json::obj([
                ("vocab", Json::num(v as f64)),
                ("dim", Json::num(d as f64)),
                ("k", Json::num(10.0)),
                ("f32_queries_per_sec", Json::num(qps["f32"])),
                ("int8_queries_per_sec", Json::num(qps["int8"])),
                ("int8_over_f32", Json::num(ratio)),
            ]),
        );
    }
    Ok(())
}

fn gemm_bench() -> anyhow::Result<()> {
    let mut table = BenchTable::new(
        "micro_gemm",
        &["kernel", "shape", "ns_per_call", "gflops"],
    );
    // The paper's window shapes: B=16, S=6, D=300.
    let (b, s, d) = (16usize, 6usize, 300usize);
    let wi = randv(b * d, 1);
    let wo = randv(s * d, 2);
    let err = randv(b * s, 3);
    let mut out_bs = vec![0.0f32; b * s];
    let mut out_bd = vec![0.0f32; b * d];
    let mut out_sd = vec![0.0f32; s * d];
    let iters = 2000;

    let st = time(100, iters, || {
        gemm_nt(b, s, d, 1.0, &wi, &wo, 0.0, &mut out_bs);
        std::hint::black_box(&out_bs);
    });
    let flops = 2.0 * b as f64 * s as f64 * d as f64;
    table.row(vec![
        "gemm_nt (logits)".into(),
        format!("[{b},{d}]x[{d},{s}]"),
        format!("{:.0}", st.median * 1e9),
        format!("{:.2}", flops / st.median / 1e9),
    ]);

    let st = time(100, iters, || {
        gemm_nn(b, d, s, 1.0, &err, &wo, 0.0, &mut out_bd);
        std::hint::black_box(&out_bd);
    });
    table.row(vec![
        "gemm_nn (dWi)".into(),
        format!("[{b},{s}]x[{s},{d}]"),
        format!("{:.0}", st.median * 1e9),
        format!("{:.2}", flops / st.median / 1e9),
    ]);

    let st = time(100, iters, || {
        gemm_tn(s, d, b, 1.0, &err, &wi, 0.0, &mut out_sd);
        std::hint::black_box(&out_sd);
    });
    table.row(vec![
        "gemm_tn (dWo)".into(),
        format!("[{s},{b}]x[{b},{d}]"),
        format!("{:.0}", st.median * 1e9),
        format!("{:.2}", flops / st.median / 1e9),
    ]);
    table.finish()
}

fn vecops_bench() -> anyhow::Result<()> {
    let mut table =
        BenchTable::new("micro_vecops", &["op", "dim", "ns_per_call"]);
    let d = 300usize;
    let a = randv(d, 4);
    let mut b = randv(d, 5);
    let st = time(1000, 20_000, || {
        std::hint::black_box(dot(&a, &b));
    });
    table.row(vec![
        "dot".into(),
        d.to_string(),
        format!("{:.1}", st.median * 1e9),
    ]);
    let st = time(1000, 20_000, || {
        axpy(0.01, &a, &mut b);
        std::hint::black_box(&b);
    });
    table.row(vec![
        "axpy".into(),
        d.to_string(),
        format!("{:.1}", st.median * 1e9),
    ]);
    table.finish()
}

fn sampler_bench() -> anyhow::Result<()> {
    let counts: HashMap<String, u64> = (0..100_000usize)
        .map(|i| (format!("w{i}"), (1_000_000_000 / (i + 1)) as u64))
        .collect();
    let vocab = Vocab::from_counts(counts, 1);
    let table_sampler = UnigramSampler::table(&vocab, 0.75, 10_000_000);
    let alias_sampler = UnigramSampler::alias(&vocab, 0.75);
    let mut rng = Xoshiro256ss::new(7);
    let mut out = BenchTable::new(
        "micro_negative_sampler",
        &["impl", "ns_per_sample"],
    );
    let st = time(2, 5, || {
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc = acc.wrapping_add(table_sampler.sample(&mut rng) as u64);
        }
        std::hint::black_box(acc);
    });
    out.row(vec![
        "original table (1e7 entries)".into(),
        format!("{:.1}", st.median * 1e3),
    ]);
    let st = time(2, 5, || {
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc = acc.wrapping_add(alias_sampler.sample(&mut rng) as u64);
        }
        std::hint::black_box(acc);
    });
    out.row(vec![
        "alias method".into(),
        format!("{:.1}", st.median * 1e3),
    ]);
    out.finish()
}

fn pjrt_call_overhead() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("micro_pjrt: artifacts not built, skipping");
        return Ok(());
    }
    let m = Manifest::load(dir)?;
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("micro_pjrt: runtime unavailable ({e}), skipping");
            return Ok(());
        }
    };
    let mut table = BenchTable::new(
        "micro_pjrt_call",
        &["variant", "W", "us_per_call", "us_per_window", "windows_per_sec"],
    );
    for name in [
        "paper_w16_b16_s6_d300",
        "paper_w64_b16_s6_d300",
        "paper_w256_b16_s6_d300",
        "jnp_paper_w64_b16_s6_d300",
    ] {
        let v = m.by_name(name)?;
        let exe = rt.compile_variant(&m, v)?;
        let wi = randv(exe.wi_len(), 8);
        let wo = randv(exe.wo_len(), 9);
        let st = time(3, 20, || {
            let r = exe.run(&wi, &wo, 0.025).unwrap();
            std::hint::black_box(r);
        });
        table.row(vec![
            name.into(),
            v.w.to_string(),
            format!("{:.0}", st.median * 1e6),
            format!("{:.1}", st.median * 1e6 / v.w as f64),
            si(v.w as f64 / st.median),
        ]);
    }
    table.finish()
}
