//! Table III — throughput comparison of the state-of-the-art word2vec
//! implementations across architectures (paper Sec. IV-B).
//!
//! REAL: all four back-ends (original scalar, BIDMach-style, ours-native,
//! ours-via-PJRT) measured single-thread on this box — the scheme
//! contrast the paper's table is about.  MODELLED: projection of the
//! scheme costs to the paper's HSW/BDW/KNL machines through the
//! calibrated coherence model.  QUOTED: the BIDMach GPU rows, exactly as
//! the paper quotes them from [10].

use pw2v::bench::{standard_workload, BenchTable};
use pw2v::config::{Backend, TrainConfig};
use pw2v::model::SharedModel;
use pw2v::perfmodel::arch;
use pw2v::perfmodel::cache::{CoherenceModel, SchemeCost};
use pw2v::train;
use pw2v::util::si;

fn main() -> anyhow::Result<()> {
    let wl = standard_workload()?;

    // Measured rows (this box, 1 thread).
    let mut measured = BenchTable::new(
        "table3_measured_this_box",
        &["code", "words_per_sec", "vs_original"],
    );
    let mut rates = Vec::new();
    for backend in [
        Backend::Scalar,
        Backend::Bidmach,
        Backend::Gemm,
        Backend::Pjrt,
    ] {
        let mut cfg = TrainConfig::default();
        cfg.backend = backend;
        cfg.threads = 1;
        cfg.dim = 300;
        // PJRT artifact geometry: W=64, B=16, S=6, D=300 is prebuilt.
        cfg.superbatch = 64;
        let model = SharedModel::init(wl.vocab.len(), cfg.dim, cfg.seed);
        let rate = match train::train(&cfg, &wl.corpus, &wl.vocab, &model) {
            Ok(out) => out.snapshot.words_per_sec(),
            Err(e) => {
                eprintln!("{backend}: skipped ({e})");
                continue;
            }
        };
        rates.push((backend, rate));
    }
    let original = rates
        .iter()
        .find(|(b, _)| *b == Backend::Scalar)
        .map(|(_, r)| *r)
        .unwrap_or(1.0);
    for (backend, rate) in &rates {
        measured.row(vec![
            backend.to_string(),
            si(*rate),
            format!("{:.2}x", rate / original),
        ]);
    }
    measured.finish()?;

    // Modelled architecture table (full machine, paper anchors) + quotes.
    let mut table = BenchTable::new(
        "table3_modelled",
        &["processor", "code", "words_per_sec", "source"],
    );
    // Per-machine 1T anchors: HSW/BDW close (similar cores), KNL cores
    // ~0.5× per-thread.
    let machines = [
        (arch::haswell(), 62_000.0, 95_000.0, 160_000.0),
        (arch::broadwell(), 70_000.0, 110_000.0, 182_000.0),
        (arch::knl(), 30_000.0, 46_000.0, 85_000.0),
    ];
    let p = 0.05; // calibrated collision mass (see perfmodel docs)
    for (m, w1_orig, w1_bid, w1_ours) in machines {
        let coh = CoherenceModel::new(m.clone(), p, 300);
        let t = m.threads();
        let rows: Vec<(&str, SchemeCost)> = vec![
            ("Original", SchemeCost::scalar(5.0, 5.0, w1_orig)),
            ("BIDMach", SchemeCost::bidmach(5.0, 5.0, w1_bid)),
            ("Our", SchemeCost::gemm(5.0, 5.0, w1_ours)),
        ];
        for (code, cost) in rows {
            // The paper only reports Original+BIDMach+Our on HSW/BDW and
            // Our on KNL; emit the same cells.
            if m.name.contains("KNL") && code != "Our" {
                continue;
            }
            table.row(vec![
                m.name.to_string(),
                code.to_string(),
                si(coh.throughput(&cost, t)),
                "modelled".to_string(),
            ]);
        }
    }
    for (name, wps) in arch::bidmach_gpu_points() {
        table.row(vec![
            name.to_string(),
            "BIDMach".to_string(),
            si(wps),
            "quoted [10]".to_string(),
        ]);
    }
    table.finish()?;
    println!(
        "\npaper Table III: Original/BIDMach/Our = 1.5M/2.4M/4.2M (HSW),\n\
         1.6M/2.5M/5.8M (BDW), Our 8.9M (KNL); K40 4.2M, Titan-X 8.5M (quoted)"
    );
    Ok(())
}
