//! Fig. 4 — multi-node scalability of distributed word2vec (paper
//! Sec. IV-C).
//!
//! REAL: the full sub-model synchronisation protocol runs at N = 1/2/4
//! replica threads (separate models, real collectives), reporting sync
//! traffic per node — the paper's network-reduction mechanism, measured.
//! MODELLED: the 1–32 node throughput curves for the BDW/FDR and KNL/OPA
//! clusters through the cluster cost model.  QUOTED: BIDMach's 1- and
//! 4-GPU points from [10].

use pw2v::bench::{standard_workload, BenchTable};
use pw2v::config::TrainConfig;
use pw2v::dist::{train_distributed, DistConfig};
use pw2v::perfmodel::arch;
use pw2v::perfmodel::simulate::{fig4_series, FigParams};
use pw2v::util::si;

fn main() -> anyhow::Result<()> {
    let wl = standard_workload()?;

    // Real protocol runs.
    let mut real = BenchTable::new(
        "fig4_protocol_runs",
        &["nodes", "sync_rounds", "rows_synced", "wire_bytes_per_node"],
    );
    for nodes in [1usize, 2, 4] {
        let mut cfg = TrainConfig::default();
        cfg.dim = 100;
        cfg.sample = 1e-3;
        let mut dist = DistConfig::for_nodes(nodes);
        dist.sync_interval = 100_000; // scaled to this corpus
        let out = train_distributed(&cfg, &dist, &wl.corpus, &wl.vocab)?;
        let st = out.sync_stats[0];
        real.row(vec![
            nodes.to_string(),
            st.rounds.to_string(),
            st.rows_synced.to_string(),
            si(st.wire_bytes as f64),
        ]);
    }
    real.finish()?;

    // Modelled Fig. 4 curves.
    let p = FigParams::default();
    let nodes = [1usize, 2, 4, 8, 16, 32];
    let bdw = fig4_series(
        &arch::broadwell(),
        arch::fdr_infiniband(),
        &p,
        182_000.0,
        &nodes,
    );
    let knl = fig4_series(&arch::knl(), arch::omnipath(), &p, 85_000.0, &nodes);
    let mut modelled = BenchTable::new(
        "fig4_modelled",
        &["nodes", "bdw_wps", "knl_wps", "bdw_efficiency"],
    );
    let bdw1 = bdw[0].words_per_sec;
    for (b, k) in bdw.iter().zip(&knl) {
        modelled.row(vec![
            b.x.to_string(),
            si(b.words_per_sec),
            si(k.words_per_sec),
            format!("{:.2}", b.words_per_sec / (b.x as f64 * bdw1)),
        ]);
    }
    modelled.finish()?;

    println!("\nBIDMach multi-GPU (quoted from [10]): 1 Titan-X = 8.5M, 4 = 20M");
    println!(
        "paper anchors: near-linear to 16 BDW / 8 KNL nodes; 110M words/s at\n\
         32 BDW nodes, 94.7M at 16 KNL nodes"
    );
    Ok(())
}
