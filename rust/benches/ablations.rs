//! Ablations of the paper's design choices (DESIGN.md §7):
//!
//!   1. input batch size B (Sec. III-C: convergence vs locality);
//!   2. superbatch width W for the PJRT path (call-overhead amortisation);
//!   3. learning-rate schedule: single decayed lr vs AdaGrad vs RMSProp —
//!      the Sec. III-E rejection, measured (throughput, accuracy, extra
//!      memory);
//!   4. sync interval sweep at N=4 (accuracy vs wire traffic).

use std::sync::Arc;

use pw2v::bench::{accuracy_workload, standard_workload, BenchTable};
use pw2v::config::{Backend, LrSchedule, TrainConfig};
use pw2v::dist::{train_distributed, DistConfig};
use pw2v::eval;
use pw2v::model::SharedModel;
use pw2v::sampling::unigram::UnigramSampler;
use pw2v::train::lr::{AdaGrad, RmsProp};
use pw2v::train::sgd_gemm::{GemmBackend, UpdateRule};
use pw2v::train::{self, trainer::train_with_factory};
use pw2v::util::si;

fn main() -> anyhow::Result<()> {
    batch_size_sweep()?;
    superbatch_sweep()?;
    lr_schedule_ablation()?;
    sync_interval_sweep()?;
    Ok(())
}

/// Ablation 1: batch size B.
fn batch_size_sweep() -> anyhow::Result<()> {
    let wl = accuracy_workload(401)?;
    let sim_set = eval::gen_similarity_set(&wl.latent, 300, 7);
    let mut table = BenchTable::new(
        "ablation_batch_size",
        &["batch_B", "words_per_sec", "similarity"],
    );
    for b in [1usize, 4, 8, 16, 32] {
        let mut cfg = TrainConfig::default();
        cfg.backend = Backend::Gemm;
        cfg.batch = b;
        cfg.dim = 100;
        cfg.epochs = 2;
        cfg.sample = 1e-3;
        cfg.lr = 0.05;
        let model = SharedModel::init(wl.vocab.len(), cfg.dim, cfg.seed);
        let out = train::train(&cfg, &wl.corpus, &wl.vocab, &model)?;
        let sim = eval::eval_similarity(&sim_set, &wl.vocab, model.m_in());
        table.row(vec![
            b.to_string(),
            si(out.snapshot.words_per_sec()),
            format!("{:.1}", sim.rho100),
        ]);
    }
    table.finish()?;
    println!("paper: B in 10-20 gives the GEMM win without hurting convergence");
    Ok(())
}

/// Ablation 2: superbatch W for the AOT/PJRT path.
fn superbatch_sweep() -> anyhow::Result<()> {
    let wl = standard_workload()?;
    let mut table = BenchTable::new(
        "ablation_superbatch_pjrt",
        &["superbatch_W", "words_per_sec", "calls"],
    );
    for w in [16usize, 64, 256] {
        let mut cfg = TrainConfig::default();
        cfg.backend = Backend::Pjrt;
        cfg.superbatch = w;
        cfg.dim = 300;
        cfg.sample = 1e-3;
        let model = SharedModel::init(wl.vocab.len(), cfg.dim, cfg.seed);
        match train::train(&cfg, &wl.corpus, &wl.vocab, &model) {
            Ok(out) => table.row(vec![
                w.to_string(),
                si(out.snapshot.words_per_sec()),
                out.snapshot.calls.to_string(),
            ]),
            Err(e) => eprintln!("W={w}: skipped ({e})"),
        }
    }
    table.finish()?;
    println!("larger W amortises the per-call PJRT overhead (DESIGN.md §8)");
    Ok(())
}

/// Ablation 3: lr schedules (the Sec. III-E rejection, measured).
fn lr_schedule_ablation() -> anyhow::Result<()> {
    let wl = accuracy_workload(402)?;
    let sim_set = eval::gen_similarity_set(&wl.latent, 300, 7);
    let mut table = BenchTable::new(
        "ablation_lr_schedule",
        &["schedule", "words_per_sec", "similarity", "extra_model_mem"],
    );
    let dim = 100;
    let schedules: Vec<(&str, UpdateRule, usize)> = vec![
        ("single-lr (paper)", UpdateRule::Plain, 0),
        (
            "adagrad",
            UpdateRule::Adagrad(Arc::new(AdaGrad::new(wl.vocab.len(), dim))),
            AdaGrad::new(wl.vocab.len(), dim).memory_bytes(),
        ),
        (
            "rmsprop",
            UpdateRule::Rmsprop(Arc::new(RmsProp::new(wl.vocab.len(), dim, 0.9))),
            RmsProp::new(wl.vocab.len(), dim, 0.9).memory_bytes(),
        ),
    ];
    for (name, rule, mem) in schedules {
        let mut cfg = TrainConfig::default();
        cfg.backend = Backend::Gemm;
        cfg.dim = dim;
        cfg.epochs = 2;
        cfg.sample = 1e-3;
        // Per-parameter schedules normalise magnitude; a smaller global
        // rate suits them.
        cfg.lr = if matches!(rule, UpdateRule::Plain) { 0.05 } else { 0.02 };
        cfg.lr_schedule = LrSchedule::Linear;
        let sampler = UnigramSampler::alias(&wl.vocab, cfg.unigram_power);
        let model = SharedModel::init(wl.vocab.len(), cfg.dim, cfg.seed);
        let rule_ref = &rule;
        let factory = |_tid: usize| -> anyhow::Result<Box<dyn train::Backend + '_>> {
            Ok(Box::new(
                GemmBackend::new(dim, 16, 6).with_rule(rule_ref.clone()),
            ))
        };
        let out = train_with_factory(
            &cfg, &wl.corpus, &wl.vocab, &model, &sampler, &factory,
        )?;
        let sim = eval::eval_similarity(&sim_set, &wl.vocab, model.m_in());
        table.row(vec![
            name.to_string(),
            si(out.snapshot.words_per_sec()),
            format!("{:.1}", sim.rho100),
            si(mem as f64),
        ]);
    }
    table.finish()?;
    println!(
        "paper Sec. III-E: per-parameter schedules cost a full extra model\n\
         of memory and bandwidth; a single decayed lr is competitive"
    );
    Ok(())
}

/// Ablation 4: sync interval at N=4.
fn sync_interval_sweep() -> anyhow::Result<()> {
    let wl = accuracy_workload(403)?;
    let sim_set = eval::gen_similarity_set(&wl.latent, 300, 7);
    let mut table = BenchTable::new(
        "ablation_sync_interval",
        &["interval_words", "similarity", "wire_bytes_per_node"],
    );
    for interval in [30_000u64, 120_000, 480_000] {
        let mut cfg = TrainConfig::default();
        cfg.dim = 100;
        cfg.epochs = 2;
        cfg.sample = 1e-3;
        cfg.lr = 0.05;
        let mut dist = DistConfig::for_nodes(4);
        dist.policy =
            pw2v::dist::SyncPolicy::submodel_for_vocab(wl.vocab.len());
        dist.sync_interval = interval;
        let out = train_distributed(&cfg, &dist, &wl.corpus, &wl.vocab)?;
        let sim = eval::eval_similarity(&sim_set, &wl.vocab, out.model.m_in());
        table.row(vec![
            interval.to_string(),
            format!("{:.1}", sim.rho100),
            si(out.sync_stats[0].wire_bytes as f64),
        ]);
    }
    table.finish()?;
    println!(
        "paper Sec. IV-C: more frequent sync holds accuracy at higher node\n\
         counts but pays traffic — the Fig. 4 sub-linear bend"
    );
    Ok(())
}
