//! Table I — predictive accuracy of the original word2vec vs our scheme
//! on three corpora of different sizes/statistics (paper Sec. IV-B).
//!
//! REAL end-to-end: three synthetic corpora (the text8 / 1B / 7.2B stand-
//! ins at this box's scale — DESIGN.md §3), both back-ends trained from
//! identical inits, evaluated on ground-truth similarity (Spearman ρ×100)
//! and planted analogies (3CosAdd exact match).  The paper's CLAIM under
//! reproduction: ours ≈ original accuracy on every corpus (Δ≈0), not the
//! absolute numbers (different corpora).

use pw2v::bench::{workload, BenchTable, Workload};
use pw2v::config::{Backend, TrainConfig};
use pw2v::corpus::synthetic::SyntheticConfig;
use pw2v::eval;
use pw2v::model::SharedModel;
use pw2v::train;

fn corpora() -> Vec<(&'static str, SyntheticConfig)> {
    vec![
        (
            "text8-class (0.6M tok)",
            SyntheticConfig {
                vocab: 5_000,
                tokens: 600_000,
                clusters: 30,
                beta: 5.0,
                seed: 101,
                ..SyntheticConfig::default()
            },
        ),
        (
            "1B-class (1.2M tok)",
            SyntheticConfig {
                vocab: 8_000,
                tokens: 1_200_000,
                clusters: 40,
                beta: 5.0,
                seed: 102,
                ..SyntheticConfig::default()
            },
        ),
        (
            "7.2B-class (2.4M tok)",
            SyntheticConfig {
                vocab: 12_000,
                tokens: 2_400_000,
                clusters: 50,
                beta: 5.0,
                seed: 103,
                ..SyntheticConfig::default()
            },
        ),
    ]
}

pub fn train_and_eval(
    wl: &Workload,
    backend: Backend,
    epochs: usize,
) -> (f64, f64) {
    let mut cfg = TrainConfig::default();
    cfg.backend = backend;
    cfg.dim = 100;
    cfg.epochs = epochs;
    cfg.sample = 1e-3;
    cfg.lr = 0.05;
    let model = SharedModel::init(wl.vocab.len(), cfg.dim, cfg.seed);
    train::train(&cfg, &wl.corpus, &wl.vocab, &model).unwrap();
    let sim_set = eval::gen_similarity_set(&wl.latent, 300, 7);
    let ana_set = eval::gen_analogy_set(&wl.latent);
    let sim = eval::eval_similarity(&sim_set, &wl.vocab, model.m_in());
    let ana = eval::eval_analogy(&ana_set, &wl.vocab, model.m_in());
    (sim.rho100, ana.accuracy100())
}

fn main() -> anyhow::Result<()> {
    let mut table = BenchTable::new(
        "table1_accuracy",
        &[
            "corpus",
            "vocab",
            "sim_original",
            "sim_ours",
            "ana_original",
            "ana_ours",
        ],
    );
    for (name, scfg) in corpora() {
        let wl = workload(scfg)?;
        eprintln!("training on {name} ...");
        let (sim_o, ana_o) = train_and_eval(&wl, Backend::Scalar, 3);
        let (sim_g, ana_g) = train_and_eval(&wl, Backend::Gemm, 3);
        table.row(vec![
            name.to_string(),
            wl.vocab.len().to_string(),
            format!("{sim_o:.1}"),
            format!("{sim_g:.1}"),
            format!("{ana_o:.1}"),
            format!("{ana_g:.1}"),
        ]);
    }
    table.finish()?;
    println!(
        "\npaper claim under reproduction: |sim_ours - sim_original| small on\n\
         every corpus (paper Table I: 66.5 vs 63.4, 64.1 vs 64.0, 69.8 vs 70.0)"
    );
    Ok(())
}
