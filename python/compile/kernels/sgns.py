"""Layer-1 Pallas kernel: fused SGNS superbatch update.

This is the paper's compute hot-spot (Ji et al. 2016, Fig. 2 right): one
window's input batch against the shared target+negatives block, expressed as
three back-to-back GEMMs fused in one kernel so the ``wi``/``wo`` blocks are
loaded into VMEM once and reused three times.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper blocks for
Xeon registers/L1 via MKL SGEMM; on TPU the analogue is keeping each
window's ``(B+S)×D`` working set resident in VMEM across the three MXU
calls.  The grid dimension runs over the ``W`` superbatched windows — the
BlockSpec index maps express the HBM→VMEM schedule that the paper's code
gets implicitly from looping over minibatches.

``interpret=True`` is REQUIRED on this box: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO so the same
program runs under the rust PJRT CPU client.  Structure (block shapes, VMEM
footprint, fusion) is what we optimise; interpret wallclock is not a TPU
proxy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgns_kernel(lr_ref, wi_ref, wo_ref, dwi_ref, dwo_ref):
    """One grid step = one window.

    Block shapes (leading 1 is the gridded window axis):
      lr_ref  : [1]        scalar learning rate (same block every step)
      wi_ref  : [1, B, D]  input-word rows
      wo_ref  : [1, S, D]  row 0 positive target, rows 1.. negatives
      dwi_ref : [1, B, D]  out: input-row deltas
      dwo_ref : [1, S, D]  out: output-row deltas
    """
    wi = wi_ref[0]  # [B, D]
    wo = wo_ref[0]  # [S, D]
    lr = lr_ref[0]

    s = wo.shape[0]
    # GEMM 1: similarity logits of every (input, sample) pair.
    logits = jnp.dot(wi, wo.T, preferred_element_type=jnp.float32)  # [B, S]
    # Label pattern [1, 0, ..., 0]: column 0 is the positive target.
    labels = (jax.lax.broadcasted_iota(jnp.int32, (1, s), 1) == 0).astype(
        logits.dtype
    )
    err = (labels - jax.nn.sigmoid(logits)) * lr  # [B, S]
    # GEMM 2 + GEMM 3: both gradients from the PRE-update blocks (the
    # paper's end-of-block update semantics).
    dwi_ref[0] = jnp.dot(err, wo, preferred_element_type=jnp.float32).astype(
        wi.dtype
    )
    dwo_ref[0] = jnp.dot(err.T, wi, preferred_element_type=jnp.float32).astype(
        wo.dtype
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def sgns_superbatch(wi, wo, lr, *, interpret: bool = True):
    """Fused SGNS deltas over a superbatch of W windows.

    Args:
      wi: f32[W, B, D] gathered input rows.
      wo: f32[W, S, D] gathered output rows (col 0 positive).
      lr: f32 scalar learning rate.
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      (dwi f32[W, B, D], dwo f32[W, S, D]) deltas to scatter-add.
    """
    w, b, d = wi.shape
    w2, s, d2 = wo.shape
    if (w, d) != (w2, d2):
        raise ValueError(f"shape mismatch wi={wi.shape} wo={wo.shape}")
    lr_arr = jnp.reshape(jnp.asarray(lr, dtype=wi.dtype), (1,))

    grid = (w,)
    return pl.pallas_call(
        _sgns_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # lr broadcast to all steps
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, b, d), wi.dtype),
            jax.ShapeDtypeStruct((w, s, d), wo.dtype),
        ],
        interpret=interpret,
    )(lr_arr, wi, wo)


def vmem_bytes(b: int, s: int, d: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step: wi + wo + dwi + dwo
    blocks plus the [B,S] logits/err intermediates.  Used by DESIGN.md's
    roofline notes and by tests that guard the footprint stays tiny."""
    blocks = 2 * (b * d + s * d)  # in + out copies
    inter = 2 * (b * s)  # logits + err
    return dtype_bytes * (blocks + inter)
