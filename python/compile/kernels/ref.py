"""Pure-jnp correctness oracle for the fused SGNS window-update kernel.

This is the mathematical ground truth the Pallas kernel (``sgns.py``) and the
AOT-lowered HLO artifact are tested against.  It implements one *superbatch*
of the paper's shared-memory scheme (Ji et al. 2016, Sec. III-B):

For each of the ``W`` windows in the superbatch we are given

  * ``wi``  — the gathered input-word rows,   shape ``[W, B, D]``
  * ``wo``  — the gathered output-word rows,  shape ``[W, S, D]``
              (row 0 = the positive target, rows 1..S-1 = the K = S-1
              negative samples *shared across the whole input batch*)
  * ``lr``  — the scalar SGD learning rate.

and compute the three GEMMs of the paper's Fig. 2 (right):

  logits = wi @ wo^T                      [W, B, S]   (GEMM 1)
  err    = (label - sigmoid(logits)) * lr [W, B, S]
  dwi    = err @ wo                       [W, B, D]   (GEMM 2)
  dwo    = err^T @ wi                     [W, S, D]   (GEMM 3)

``label`` is 1 for column 0 (the positive target) and 0 for the negative
columns — exactly the ``label - sigma(inn)`` error of Algorithm 1, batched.

The function returns *deltas* ``(dwi, dwo)`` rather than updated rows: the
rust coordinator scatter-ADDS them into the shared model, which preserves
Hogwild semantics under concurrent writers (see DESIGN.md Sec. 2).

Gradient notes (matches Algorithm 1 of the paper):
  * Both ``dwi`` and ``dwo`` are computed from the PRE-update matrices —
    the paper's scheme batches all updates to the end of the GEMM block.
  * No normalization by B or S: word2vec applies the raw per-pair gradient,
    so the batched form is the straight sum over pairs.
"""

from __future__ import annotations

import jax.numpy as jnp


def sigmoid(x):
    """Numerically-stable logistic function (matches jax.nn.sigmoid)."""
    return jnp.where(
        x >= 0,
        1.0 / (1.0 + jnp.exp(-x)),
        jnp.exp(x) / (1.0 + jnp.exp(x)),
    )


def label_row(s: int, dtype=jnp.float32):
    """The shared label pattern: [1, 0, 0, ..., 0] of length S."""
    return jnp.concatenate(
        [jnp.ones((1,), dtype=dtype), jnp.zeros((s - 1,), dtype=dtype)]
    )


def sgns_window_grads(wi, wo, lr):
    """SGNS deltas for a single window.

    Args:
      wi: [B, D] input-word rows.
      wo: [S, D] output rows (row 0 positive, rest shared negatives).
      lr: scalar learning rate.
    Returns:
      (dwi [B, D], dwo [S, D]) — deltas to scatter-add into the model.
    """
    b, d = wi.shape
    s, d2 = wo.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    logits = wi @ wo.T  # [B, S]
    labels = label_row(s, wi.dtype)[None, :]  # [1, S]
    err = (labels - sigmoid(logits)) * lr  # [B, S]
    dwi = err @ wo  # [B, D]
    dwo = err.T @ wi  # [S, D]
    return dwi, dwo


def sgns_superbatch_grads(wi, wo, lr):
    """SGNS deltas for a whole superbatch.

    Args:
      wi: [W, B, D]; wo: [W, S, D]; lr: scalar.
    Returns:
      (dwi [W, B, D], dwo [W, S, D]).
    """
    w, b, d = wi.shape
    w2, s, d2 = wo.shape
    assert w == w2 and d == d2
    logits = jnp.einsum("wbd,wsd->wbs", wi, wo)
    labels = label_row(s, wi.dtype)[None, None, :]
    err = (labels - sigmoid(logits)) * lr
    dwi = jnp.einsum("wbs,wsd->wbd", err, wo)
    dwo = jnp.einsum("wbs,wbd->wsd", err, wi)
    return dwi, dwo


def sgns_objective(wi, wo):
    """The (maximised) negative-sampling objective of Eq. (3), summed over
    the superbatch.  Used by tests to check the deltas are an ascent
    direction, and by the convergence tests as a loss proxy."""
    logits = jnp.einsum("wbd,wsd->wbs", wi, wo)
    s = logits.shape[-1]
    labels = label_row(s, wi.dtype)[None, None, :]
    # log sigma(x) for positives, log sigma(-x) for negatives
    signed = jnp.where(labels > 0, logits, -logits)
    # log(sigmoid(z)) = -softplus(-z), stable
    return -jnp.sum(jnp.logaddexp(0.0, -signed))
