"""Layer-2 JAX model: the SGNS superbatch train step.

Build-time only — lowered once by ``aot.py`` to HLO text and never imported
at runtime.  The step the rust coordinator executes per superbatch is

    step : (wi[W,B,D], wo[W,S,D], lr) -> (dwi[W,B,D], dwo[W,S,D])

where the gather (model rows -> wi/wo) and the Hogwild scatter-add
(dwi/dwo -> model rows) live in rust (Layer 3), because they touch the
shared mutable model.  The pure-functional GEMM core is what XLA sees.

Two implementations of the same math:
  * ``step_pallas``  — calls the Layer-1 Pallas kernel (the shipped path).
  * ``step_jnp``     — pure-jnp einsum variant (reference / A-B testing;
                       also the oracle the kernel is tested against).

Also here: ``softmax_step`` — the full-softmax Skip-gram of Eq. (2), used
only by tests to validate that negative sampling approximates its gradient
direction (never exported: cost ∝ V).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.sgns import sgns_superbatch


def step_pallas(wi, wo, lr):
    """Shipped train step: fused Pallas SGNS kernel over the superbatch."""
    return sgns_superbatch(wi, wo, lr, interpret=True)


def step_jnp(wi, wo, lr):
    """Reference train step: same math in pure jnp (XLA-fused einsums)."""
    return ref.sgns_superbatch_grads(wi, wo, lr)


def softmax_step(wi, m_out, target, lr):
    """Full-softmax Skip-gram gradient of Eq. (2) for one window.

    Args:
      wi: [B, D] input rows; m_out: [V, D] full output matrix;
      target: int32 scalar target word id; lr: scalar.
    Returns:
      (dwi [B, D], dm_out [V, D]).  Test-only: cost is O(V*D).
    """
    logits = wi @ m_out.T  # [B, V]
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(target, m_out.shape[0], dtype=wi.dtype)[None, :]
    err = (onehot - p) * lr  # [B, V]
    dwi = err @ m_out
    dm_out = err.T @ wi
    return dwi, dm_out


def shapes(w: int, b: int, s: int, d: int, dtype=jnp.float32):
    """ShapeDtypeStructs for AOT lowering of a (W,B,S,D) step variant."""
    return (
        jax.ShapeDtypeStruct((w, b, d), dtype),
        jax.ShapeDtypeStruct((w, s, d), dtype),
        jax.ShapeDtypeStruct((), dtype),
    )
