"""AOT compile path: lower the Layer-2 step to HLO **text** artifacts.

Run once by ``make artifacts``; python never runs on the train path.  The
rust runtime (``rust/src/runtime/``) loads these with
``HloModuleProto::from_text_file`` → ``PjRtClient::compile`` → ``execute``.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly.  See /opt/xla-example/README.md.

Each exported variant is a fixed-shape ``step(wi[W,B,D], wo[W,S,D], lr)``;
``manifest.json`` indexes them so the rust side picks the variant matching
its configured superbatch geometry.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# Geometries the rust coordinator may request:
#   - test:   tiny, compiles fast, used by rust unit/integration tests
#   - quick:  the examples/quickstart geometry
#   - paper:  the paper's 1B-benchmark parameters (D=300, K=5 -> S=6,
#             context batch ~2*window=10..20 -> B=16) at several superbatch
#             widths W for the call-amortisation ablation
#
# EVERY geometry is emitted through BOTH L2 paths:
#   - "pallas": the fused L1 kernel under interpret=True.  This is the
#     TPU-structured artifact; on the CPU PJRT client its grid loop
#     executes serially with per-step buffer copies and measures ~9x
#     slower (EXPERIMENTS.md §Perf), so it is kept for structure
#     validation and TPU hand-off.
#   - "jnp": the same step as XLA-fused einsums — what the rust trainer
#     executes by default on CPU (numerically identical; tested).
GEOMETRIES = [
    ("test_w4_b8_s6_d32", 4, 8, 6, 32),
    ("quick_w16_b16_s6_d64", 16, 16, 6, 64),
    ("paper_w16_b16_s6_d300", 16, 16, 6, 300),
    ("paper_w64_b16_s6_d300", 64, 16, 6, 300),
    ("paper_w256_b16_s6_d300", 256, 16, 6, 300),
]

VARIANTS = [(name, "pallas", w, b, s, d) for name, w, b, s, d in GEOMETRIES] + [
    (f"jnp_{name}", "jnp", w, b, s, d) for name, w, b, s, d in GEOMETRIES
]

STEP_FNS = {"pallas": model.step_pallas, "jnp": model.step_jnp}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(kind: str, w: int, b: int, s: int, d: int) -> str:
    fn = STEP_FNS[kind]
    lowered = jax.jit(fn).lower(*model.shapes(w, b, s, d))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated variant names to build"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {"format": "hlo-text", "entries": []}
    for name, kind, w, b, s, d in VARIANTS:
        if only and name not in only:
            continue
        text = lower_variant(kind, w, b, s, d)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "kind": kind,
                "w": w,
                "b": b,
                "s": s,
                "d": d,
                "dtype": "f32",
                "sha256_16": digest,
                # inputs: wi[W,B,D], wo[W,S,D], lr[] ; outputs (tuple):
                # dwi[W,B,D], dwo[W,S,D]
                "inputs": [[w, b, d], [w, s, d], []],
                "outputs": [[w, b, d], [w, s, d]],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['entries'])} variants)")


if __name__ == "__main__":
    main()
