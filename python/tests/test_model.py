"""Layer-2 tests: the train step variants, the full-softmax reference, and
the AOT lowering path (HLO text generation + manifest geometry)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref


def rand(shape, seed, scale=0.1):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


class TestStepVariants:
    def test_pallas_and_jnp_steps_agree(self):
        wi, wo = rand((8, 16, 64), 0), rand((8, 6, 64), 1)
        p = model.step_pallas(wi, wo, 0.025)
        j = model.step_jnp(wi, wo, 0.025)
        np.testing.assert_allclose(p[0], j[0], rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(p[1], j[1], rtol=1e-5, atol=1e-6)

    @given(
        w=st.integers(1, 6),
        b=st.integers(1, 16),
        s=st.integers(2, 8),
        d=st.sampled_from([4, 32, 300]),
    )
    @settings(max_examples=15, deadline=None)
    def test_agreement_sweep(self, w, b, s, d):
        wi, wo = rand((w, b, d), 2), rand((w, s, d), 3)
        p = model.step_pallas(wi, wo, 0.05)
        j = model.step_jnp(wi, wo, 0.05)
        np.testing.assert_allclose(p[0], j[0], rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(p[1], j[1], rtol=1e-4, atol=1e-6)

    def test_shapes_helper(self):
        shapes = model.shapes(4, 8, 6, 32)
        assert shapes[0].shape == (4, 8, 32)
        assert shapes[1].shape == (4, 6, 32)
        assert shapes[2].shape == ()


class TestSoftmaxReference:
    """Negative sampling must approximate the full-softmax gradient
    direction (Eq. 2 vs Eq. 3 of the paper)."""

    def test_negative_sampling_aligns_with_softmax(self):
        v, d, b = 50, 16, 4
        m_out = rand((v, d), 4)
        wi = rand((b, d), 5)
        target = jnp.int32(7)
        dwi_sm, _ = model.softmax_step(wi, m_out, target, 1.0)

        # Average many negative-sampling gradient estimates.
        acc = jnp.zeros_like(wi)
        k = jax.random.PRNGKey(6)
        n_est = 200
        for i in range(n_est):
            k, sub = jax.random.split(k)
            negs = jax.random.randint(sub, (5,), 0, v)
            outs = jnp.concatenate([jnp.array([7]), negs])
            wo = m_out[outs]
            dwi, _ = ref.sgns_window_grads(wi, wo, 1.0)
            acc = acc + dwi
        acc = acc / n_est

        # Cosine between the flattened gradients should be clearly positive.
        cos = jnp.vdot(acc, dwi_sm) / (
            jnp.linalg.norm(acc) * jnp.linalg.norm(dwi_sm) + 1e-9
        )
        assert float(cos) > 0.5, f"cos={float(cos)}"

    def test_softmax_step_shapes(self):
        v, d, b = 20, 8, 3
        dwi, dm = model.softmax_step(
            rand((b, d), 7), rand((v, d), 8), jnp.int32(3), 0.1
        )
        assert dwi.shape == (b, d)
        assert dm.shape == (v, d)


class TestAotLowering:
    def test_hlo_text_is_parseable_hlo(self):
        text = aot.lower_variant("pallas", 2, 4, 3, 8)
        assert "HloModule" in text
        assert "f32[2,4,8]" in text  # wi param shape
        assert "f32[2,3,8]" in text  # wo param shape

    def test_jnp_variant_lowers_too(self):
        text = aot.lower_variant("jnp", 2, 4, 3, 8)
        assert "HloModule" in text

    def test_variant_table_geometry_consistent(self):
        for name, kind, w, b, s, d in aot.VARIANTS:
            assert kind in aot.STEP_FNS
            assert all(x > 0 for x in (w, b, s, d))
            assert f"w{w}" in name and f"d{d}" in name

    def test_deterministic_lowering(self):
        a = aot.lower_variant("pallas", 1, 2, 2, 4)
        b = aot.lower_variant("pallas", 1, 2, 2, 4)
        assert a == b


class TestObjective:
    def test_objective_improves_with_deltas(self):
        wi, wo = rand((4, 8, 32), 9), rand((4, 6, 32), 10)
        before = ref.sgns_objective(wi, wo)
        dwi, dwo = model.step_pallas(wi, wo, 0.1)
        after = ref.sgns_objective(wi + dwi, wo + dwo)
        assert float(after) > float(before)

    @pytest.mark.parametrize("s", [2, 6, 11])
    def test_label_pattern(self, s):
        lab = ref.label_row(s)
        assert lab[0] == 1.0
        assert jnp.sum(lab) == 1.0
