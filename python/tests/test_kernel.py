"""Layer-1 correctness: the fused Pallas SGNS kernel vs. the pure-jnp oracle.

This is the CORE numeric signal of the whole stack: if these pass, the HLO
artifact the rust coordinator executes computes exactly the batched
Algorithm-1 gradients of the paper.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sgns import sgns_superbatch, vmem_bytes


def rand(shape, seed, scale=0.1, dtype=jnp.float32):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale).astype(dtype)


def assert_matches_ref(w, b, s, d, lr, seed=0, rtol=1e-5, atol=1e-6):
    wi = rand((w, b, d), seed)
    wo = rand((w, s, d), seed + 1)
    dwi, dwo = sgns_superbatch(wi, wo, lr)
    rwi, rwo = ref.sgns_superbatch_grads(wi, wo, lr)
    np.testing.assert_allclose(dwi, rwi, rtol=rtol, atol=atol)
    np.testing.assert_allclose(dwo, rwo, rtol=rtol, atol=atol)


class TestKernelVsRef:
    def test_paper_geometry(self):
        """The paper's 1B-benchmark parameters: D=300, K=5, B=16."""
        assert_matches_ref(w=8, b=16, s=6, d=300, lr=0.025)

    def test_tiny(self):
        assert_matches_ref(w=1, b=1, s=2, d=4, lr=0.5)

    def test_single_window(self):
        assert_matches_ref(w=1, b=16, s=6, d=300, lr=0.025)

    def test_wide_superbatch(self):
        assert_matches_ref(w=64, b=8, s=6, d=64, lr=0.01)

    def test_large_lr(self):
        assert_matches_ref(w=4, b=8, s=6, d=32, lr=1.0)

    def test_zero_lr_gives_zero_deltas(self):
        wi, wo = rand((4, 8, 32), 0), rand((4, 6, 32), 1)
        dwi, dwo = sgns_superbatch(wi, wo, 0.0)
        assert float(jnp.abs(dwi).max()) == 0.0
        assert float(jnp.abs(dwo).max()) == 0.0

    @given(
        w=st.integers(1, 8),
        b=st.integers(1, 20),
        s=st.integers(2, 12),
        d=st.sampled_from([1, 3, 8, 32, 100, 300]),
        lr=st.floats(1e-4, 1.0),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=40, deadline=None)
    def test_hypothesis_shape_sweep(self, w, b, s, d, lr, seed):
        """The harness-mandated hypothesis sweep over kernel shapes."""
        assert_matches_ref(w, b, s, d, lr, seed=seed)

    def test_shape_mismatch_raises(self):
        wi, wo = rand((4, 8, 32), 0), rand((3, 6, 32), 1)
        with pytest.raises(ValueError):
            sgns_superbatch(wi, wo, 0.025)


class TestKernelSemantics:
    """Checks of the SGNS math itself, independent of the oracle."""

    def test_deltas_are_ascent_direction(self):
        """Applying the deltas must increase the Eq. (3) objective."""
        wi, wo = rand((8, 16, 64), 3), rand((8, 6, 64), 4)
        before = ref.sgns_objective(wi, wo)
        dwi, dwo = sgns_superbatch(wi, wo, 0.05)
        after = ref.sgns_objective(wi + dwi, wo + dwo)
        assert float(after) > float(before)

    def test_positive_column_pulls_together(self):
        """Gradient on the positive pair increases its dot product."""
        wi = rand((1, 1, 16), 5)
        wo = rand((1, 6, 16), 6)
        dwi, dwo = sgns_superbatch(wi, wo, 0.1)
        before = float(jnp.vdot(wi[0, 0], wo[0, 0]))
        after = float(jnp.vdot(wi[0, 0] + dwi[0, 0], wo[0, 0] + dwo[0, 0]))
        assert after > before

    def test_negative_columns_push_apart(self):
        """Gradient on each negative pair decreases its dot product when
        the current similarity is positive."""
        # Make all vectors positively aligned so sigma(logit) > 0.5.
        wi = jnp.abs(rand((1, 4, 16), 7)) + 0.5
        wo = jnp.abs(rand((1, 6, 16), 8)) + 0.5
        dwi, dwo = sgns_superbatch(wi, wo, 0.05)
        for k in range(1, 6):
            before = float(jnp.vdot(wi[0, 0], wo[0, k]))
            after = float(
                jnp.vdot(wi[0, 0] + dwi[0, 0], wo[0, k] + dwo[0, k])
            )
            assert after < before, f"negative sample {k} not pushed apart"

    def test_windows_independent(self):
        """Each window's deltas depend only on that window's rows."""
        wi, wo = rand((4, 8, 32), 9), rand((4, 6, 32), 10)
        dwi_all, dwo_all = sgns_superbatch(wi, wo, 0.025)
        dwi_one, dwo_one = sgns_superbatch(wi[1:2], wo[1:2], 0.025)
        np.testing.assert_allclose(dwi_all[1:2], dwi_one, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(dwo_all[1:2], dwo_one, rtol=1e-5, atol=1e-7)

    def test_lr_scales_linearly(self):
        wi, wo = rand((2, 8, 32), 11), rand((2, 6, 32), 12)
        d1, _ = sgns_superbatch(wi, wo, 0.01)
        d2, _ = sgns_superbatch(wi, wo, 0.02)
        np.testing.assert_allclose(2.0 * d1, d2, rtol=1e-4, atol=1e-7)

    def test_shared_negative_reduction(self):
        """dwo for a negative row must equal the SUM of per-input
        contributions — the register/cache reduction the paper credits for
        cutting model-update traffic (Sec. III-C)."""
        wi, wo = rand((1, 8, 32), 13), rand((1, 6, 32), 14)
        _, dwo = sgns_superbatch(wi, wo, 0.05)
        acc = np.zeros((6, 32), np.float32)
        for i in range(8):
            _, dwo_i = ref.sgns_window_grads(wi[0, i : i + 1], wo[0], 0.05)
            acc += np.asarray(dwo_i)
        np.testing.assert_allclose(dwo[0], acc, rtol=1e-4, atol=1e-6)


class TestVmemFootprint:
    def test_paper_config_fits_easily(self):
        """DESIGN.md §Hardware-Adaptation: one grid step's working set at
        paper parameters is tiny relative to a 16 MB VMEM."""
        assert vmem_bytes(b=16, s=6, d=300) < 128 * 1024

    def test_footprint_formula(self):
        assert vmem_bytes(b=1, s=1, d=1) == 4 * (2 * 2 + 2)
