"""L2 structural perf checks on the lowered HLO (EXPERIMENTS.md §Perf):
exactly the paper's three GEMMs, no accidental recompute of them, and both
lowerings carry the same entry layout."""

import re

from compile import aot


def count_dots(hlo: str) -> int:
    # e.g. "dot.3 = f32[64,16,6]{2,1,0} dot(Arg_0.3, Arg_1.3), ..."
    return len(re.findall(r"\{[\d,]*\} dot\(", hlo))


class TestHloStructure:
    def test_jnp_step_has_exactly_three_gemms(self):
        hlo = aot.lower_variant("jnp", 8, 16, 6, 300)
        assert count_dots(hlo) == 3, hlo

    def test_entry_layout_matches_contract(self):
        hlo = aot.lower_variant("jnp", 8, 16, 6, 300)
        # inputs: wi[W,B,D], wo[W,S,D], lr[] ; outputs: (dwi, dwo)
        assert "f32[8,16,300]" in hlo
        assert "f32[8,6,300]" in hlo
        header = hlo.splitlines()[0]
        assert "(f32[8,16,300]" in header and "->(f32[8,16,300]" in header

    def test_pallas_lowering_contains_grid_loop(self):
        # interpret-mode pallas lowers to a while loop over the W grid —
        # the structural reason the CPU trainer prefers the jnp artifact
        # (documented; see EXPERIMENTS.md §Perf).
        hlo = aot.lower_variant("pallas", 8, 16, 6, 300)
        assert "while" in hlo
        # The fused kernel still performs its three dots per grid step.
        assert count_dots(hlo) == 3, count_dots(hlo)

    def test_batch_dims_used_not_unrolled(self):
        # The W axis must be a dot batch dimension (one batched GEMM),
        # not W separate dots.
        hlo = aot.lower_variant("jnp", 16, 16, 6, 300)
        assert count_dots(hlo) == 3
        assert "lhs_batch_dims={0}" in hlo
