//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): exercises the full three-layer
//! system on a real small workload, proving all layers compose:
//!
//!   * generates a 3M-token corpus with planted semantics (~9M-param
//!     model at V=15K, D=300 — word2vec's Ω = 2·V·D);
//!   * epoch 1 trains THROUGH the AOT JAX/Pallas artifact via PJRT — the
//!     L1/L2/L3 composition path — and must improve the objective;
//!   * epochs 2-4 train with the native GEMM scheme, logging the
//!     negative-sampling objective (loss curve) and throughput;
//!   * evaluates similarity + analogy against ground truth;
//!   * compares against the original-scheme baseline trained identically.
//!
//! Run with:  cargo run --release --example train_full_stack

use pw2v::config::Backend;
use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::eval;
use pw2v::SharedModel;
use pw2v::sampling::batch::BatchBuilder;
use pw2v::sampling::unigram::UnigramSampler;
use pw2v::train::{self, ns_objective};
use pw2v::util::rng::Xoshiro256ss;
use pw2v::util::si;

fn main() -> anyhow::Result<()> {
    // ---- workload -------------------------------------------------------
    // ~9M-parameter model: V=15K retained words x D=300 x 2 matrices,
    // 3M tokens (~200 occurrences/word — enough signal to learn from; a
    // larger vocabulary at this corpus size underfits for BOTH schemes).
    let scfg = SyntheticConfig {
        vocab: 15_000,
        tokens: 3_000_000,
        clusters: 60,
        beta: 5.5,
        relations: 8,
        pairs_per_relation: 12,
        seed: 4242,
        ..SyntheticConfig::default()
    };
    let latent = LatentModel::new(scfg);
    std::fs::create_dir_all("bench_data")?;
    let corpus = std::path::PathBuf::from("bench_data/e2e_corpus_v2.txt");
    if !corpus.exists() {
        eprintln!("generating 3M-token corpus ...");
        latent.write_corpus(&corpus)?;
    }
    let vocab = Vocab::build_from_file(&corpus, 3)?;
    let dim = 300;
    let params = 2 * vocab.len() * dim;
    println!(
        "corpus {} tokens | vocab {} | model 2x{}x{} = {} params ({} MB)",
        vocab.total_words(),
        vocab.len(),
        vocab.len(),
        dim,
        si(params as f64),
        params * 4 / (1024 * 1024),
    );

    // Held-out probe windows for the loss curve.
    let sampler = UnigramSampler::alias(&vocab, 0.75);
    let builder = BatchBuilder::new(&sampler, 5, 16, 5);
    let mut rng = Xoshiro256ss::new(99);
    let probe: Vec<_> = (0..64)
        .flat_map(|_| builder.windows_of(&latent.sentence(&mut rng), &mut rng))
        .take(512)
        .collect();

    // ---- our scheme: segmented training with loss logging ---------------
    // Epoch 1 runs THROUGH THE AOT/PJRT ARTIFACT (the L1+L2+L3 composition
    // path, where the improvement signal is unambiguous on a fresh model);
    // later epochs run the native GEMM back-end.  Per-segment lr declines
    // (each train() call owns one epoch's schedule).
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::Gemm;
    cfg.dim = dim;
    cfg.sample = 1e-3;
    cfg.epochs = 1;
    let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);

    println!("\n== loss curve (negative-sampling objective on 512 probe windows) ==");
    println!("{:>14}  {:>14}  {:>12}", "epoch", "objective", "words/sec");
    let init_obj = ns_objective(&model, &probe);
    println!("{:>14}  {:>14.1}  {:>12}", "init", init_obj, "-");
    let mut total_words = 0u64;
    let mut total_secs = 0.0;

    // Epoch 1: the PJRT artifact path.
    let prev_obj = init_obj;
    let mut pjrt_ok = false;
    {
        let mut pjrt_cfg = cfg.clone();
        pjrt_cfg.backend = Backend::Pjrt;
        pjrt_cfg.superbatch = 64; // matches (jnp_)paper_w64_b16_s6_d300
        pjrt_cfg.lr = 0.035;
        match train::train(&pjrt_cfg, &corpus, &vocab, &model) {
            Ok(out) => {
                let obj = ns_objective(&model, &probe);
                println!(
                    "{:>14}  {:>14.1}  {:>12}",
                    "1 (pjrt)",
                    obj,
                    si(out.snapshot.words_per_sec())
                );
                anyhow::ensure!(
                    obj > prev_obj,
                    "PJRT epoch failed to improve the objective"
                );
                pjrt_ok = true;
                total_words += out.snapshot.words;
                total_secs += out.snapshot.secs;
            }
            Err(e) => println!("pjrt epoch skipped (artifacts missing?): {e}"),
        }
    }

    // Remaining epochs: native GEMM back-end, the standard word2vec
    // schedule per epoch (same budget the scalar baseline gets below).
    let mut gemm_words = 0u64;
    let mut gemm_secs = 0.0f64;
    for epoch in 2..=4 {
        cfg.lr = 0.025;
        let out = train::train(&cfg, &corpus, &vocab, &model)?;
        total_words += out.snapshot.words;
        total_secs += out.snapshot.secs;
        gemm_words += out.snapshot.words;
        gemm_secs += out.snapshot.secs;
        let obj = ns_objective(&model, &probe);
        println!(
            "{:>14}  {:>14.1}  {:>12}",
            format!("{epoch} (gemm)"),
            obj,
            si(out.snapshot.words_per_sec())
        );
    }
    println!(
        "composition: PJRT epoch {} (objective {:.1} -> {:.1} across run)",
        if pjrt_ok { "improved the model ✓" } else { "SKIPPED" },
        init_obj,
        ns_objective(&model, &probe)
    );

    // ---- evaluation ------------------------------------------------------
    let sim_set = eval::gen_similarity_set(&latent, 350, 7);
    let ana_set = eval::gen_analogy_set(&latent);
    let sim = eval::eval_similarity(&sim_set, &vocab, model.m_in());
    let ana = eval::eval_analogy(&ana_set, &vocab, model.m_in());
    println!("\n== evaluation (ours) ==");
    println!(
        "similarity rho100 = {:.1} ({} pairs) | analogy = {:.1}% ({} questions)",
        sim.rho100,
        sim.pairs_covered,
        ana.accuracy100(),
        ana.covered
    );
    println!(
        "aggregate: {} words in {:.0}s = {} words/sec",
        total_words,
        total_secs,
        si(total_words as f64 / total_secs.max(1e-9))
    );

    // ---- original-scheme baseline ---------------------------------------
    println!("\n== baseline: original scheme (scalar Hogwild), same budget ==");
    let mut base_cfg = cfg.clone();
    base_cfg.backend = Backend::Scalar;
    base_cfg.lr = 0.025;
    base_cfg.epochs = 4;
    let base_model = SharedModel::init(vocab.len(), dim, cfg.seed);
    let base_out = train::train(&base_cfg, &corpus, &vocab, &base_model)?;
    let bsim = eval::eval_similarity(&sim_set, &vocab, base_model.m_in());
    let bana = eval::eval_analogy(&ana_set, &vocab, base_model.m_in());
    println!(
        "original: {} words/sec | similarity {:.1} | analogy {:.1}%",
        si(base_out.snapshot.words_per_sec()),
        bsim.rho100,
        bana.accuracy100()
    );
    println!(
        "\nheadline: ours(native gemm)/original throughput = {:.2}x (paper: 2.6x @1T)\n\
         accuracy delta: similarity {:+.1}, analogy {:+.1} (paper: ~0)",
        (gemm_words as f64 / gemm_secs.max(1e-9))
            / base_out.snapshot.words_per_sec(),
        sim.rho100 - bsim.rho100,
        ana.accuracy100() - bana.accuracy100()
    );
    Ok(())
}
