//! Distributed training demo: four replica "nodes" train on corpus shards
//! with the paper's sub-model synchronisation and node-scaled learning
//! rate, then the merged model is compared against a single-node run —
//! the Sec. III-E protocol end to end, with traffic accounting.
//!
//! Run with:  cargo run --release --example distributed_sim

use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::dist::{train_distributed, DistConfig, SyncPolicy};
use pw2v::eval;
use pw2v::SharedModel;
use pw2v::train;
use pw2v::util::si;

fn main() -> anyhow::Result<()> {
    let scfg = SyntheticConfig {
        vocab: 8_000,
        tokens: 1_500_000,
        clusters: 40,
        beta: 5.0,
        seed: 777,
        ..SyntheticConfig::default()
    };
    let latent = LatentModel::new(scfg);
    let corpus = std::env::temp_dir().join("pw2v_dist_demo_corpus.txt");
    if !corpus.exists() {
        eprintln!("generating corpus ...");
        latent.write_corpus(&corpus)?;
    }
    let vocab = Vocab::build_from_file(&corpus, 2)?;
    let sim_set = eval::gen_similarity_set(&latent, 300, 7);

    let mut cfg = TrainConfig::default();
    cfg.dim = 100;
    cfg.epochs = 2;
    cfg.sample = 1e-3;
    cfg.lr = 0.05;

    // Single-node reference.
    let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);
    let single = train::train(&cfg, &corpus, &vocab, &model)?;
    let single_sim = eval::eval_similarity(&sim_set, &vocab, model.m_in());
    println!(
        "single node : rho100 {:.1} | {} words",
        single_sim.rho100, single.snapshot.words
    );

    // Four nodes, sub-model sync (the paper's configuration).
    for (name, policy) in [
        ("full sync  ", SyncPolicy::Full),
        ("sub-model  ", SyncPolicy::submodel_for_vocab(vocab.len())),
    ] {
        let mut dist = DistConfig::for_nodes(4);
        dist.sync_interval = 75_000;
        dist.policy = policy;
        let out = train_distributed(&cfg, &dist, &corpus, &vocab)?;
        let sim = eval::eval_similarity(&sim_set, &vocab, out.model.m_in());
        let st = out.sync_stats[0];
        println!(
            "4 nodes {name}: rho100 {:.1} | {} rounds | {} wire bytes/node",
            sim.rho100,
            st.rounds,
            si(st.wire_bytes as f64)
        );
    }
    println!(
        "\nexpected: sub-model sync holds accuracy close to full sync at a\n\
         fraction of the traffic (paper Sec. III-E / Table IV)"
    );
    Ok(())
}
