//! Scaling-curve generator: measures this box's real single-thread rates,
//! then prints the paper's Fig. 3 (threads) and Fig. 4 (nodes) curves from
//! the calibrated performance models — the projection half of DESIGN.md §3's
//! hardware substitution.
//!
//! Run with:  cargo run --release --example scaling_curves

use pw2v::bench::workload;
use pw2v::TrainConfig;
use pw2v::corpus::synthetic::SyntheticConfig;
use pw2v::perfmodel::arch;
use pw2v::perfmodel::calibrate::Calibration;
use pw2v::perfmodel::simulate::{
    fig3_series, fig3_thread_axis, fig4_series, FigParams,
};
use pw2v::util::si;

fn main() -> anyhow::Result<()> {
    // Calibrate on a small corpus (real measurement, this box).
    let wl = workload(SyntheticConfig {
        vocab: 10_000,
        tokens: 500_000,
        clusters: 40,
        seed: 11,
        ..SyntheticConfig::default()
    })?;
    let mut cfg = TrainConfig::default();
    cfg.dim = 300;
    cfg.sample = 1e-3;
    eprintln!("calibrating single-thread rates (real runs) ...");
    let cal = Calibration::measure(&cfg, &wl.corpus, &wl.vocab, false)?;
    println!(
        "measured 1T: original {} | bidmach {} | ours {}  (ours/original = {:.2}x; paper 2.6x)",
        si(cal.scalar_w1),
        si(cal.bidmach_w1),
        si(cal.gemm_w1),
        cal.gemm_over_scalar()
    );

    // Project Fig. 3 with the MEASURED ratio re-anchored to the paper's
    // absolute 1T scalar rate (this vCPU's absolute speed differs).
    let p = FigParams::default();
    let bdw = arch::broadwell();
    let w1_scalar = 70_000.0;
    let w1_gemm = w1_scalar * cal.gemm_over_scalar();
    let axis = fig3_thread_axis(&bdw);
    let (s_curve, g_curve) = fig3_series(&bdw, &p, w1_scalar, w1_gemm, &axis);
    println!("\nFig 3 (Broadwell, modelled from measured ratio):");
    println!("{:>8} {:>12} {:>12} {:>8}", "threads", "original", "ours", "ratio");
    for (s, g) in s_curve.iter().zip(&g_curve) {
        println!(
            "{:>8} {:>12} {:>12} {:>7.2}x",
            s.x,
            si(s.words_per_sec),
            si(g.words_per_sec),
            g.words_per_sec / s.words_per_sec
        );
    }

    let nodes = [1usize, 2, 4, 8, 16, 32];
    println!("\nFig 4 (clusters, modelled):");
    println!("{:>8} {:>14} {:>14}", "nodes", "BDW+FDR", "KNL+OPA");
    let bdw_series =
        fig4_series(&bdw, arch::fdr_infiniband(), &p, w1_gemm, &nodes);
    let knl_series = fig4_series(
        &arch::knl(),
        arch::omnipath(),
        &p,
        w1_gemm * 85.0 / 182.0,
        &nodes,
    );
    for (b, k) in bdw_series.iter().zip(&knl_series) {
        println!(
            "{:>8} {:>14} {:>14}",
            b.x,
            si(b.words_per_sec),
            si(k.words_per_sec)
        );
    }
    println!("\npaper anchors: 5.8M @72T BDW; 110M @32 BDW nodes; 94.7M @16 KNL");
    Ok(())
}
