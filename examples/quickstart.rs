//! Quickstart: generate a small corpus, train word2vec with the paper's
//! GEMM scheme, evaluate, and inspect nearest neighbours — the 60-second
//! tour of the public API.
//!
//! Run with:  cargo run --release --example quickstart

use pw2v::config::Backend;
use pw2v::TrainConfig;
use pw2v::corpus::synthetic::{LatentModel, SyntheticConfig};
use pw2v::Vocab;
use pw2v::eval;
use pw2v::eval::similarity::cosine;
use pw2v::SharedModel;
use pw2v::train;
use pw2v::util::si;

fn main() -> anyhow::Result<()> {
    // 1. A synthetic corpus with known semantic structure (stands in for
    //    text8; see DESIGN.md §6).
    let scfg = SyntheticConfig {
        vocab: 3_000,
        tokens: 400_000,
        clusters: 25,
        beta: 5.0,
        ..SyntheticConfig::default()
    };
    let latent = LatentModel::new(scfg);
    let corpus = std::env::temp_dir().join("pw2v_quickstart_corpus.txt");
    let n = latent.write_corpus(&corpus)?;
    println!("corpus: {n} tokens");

    // 2. Vocabulary + model.
    let vocab = Vocab::build_from_file(&corpus, 2)?;
    println!("vocab: {} words", vocab.len());
    let mut cfg = TrainConfig::default();
    cfg.backend = Backend::Gemm; // the paper's scheme
    cfg.dim = 64;
    cfg.epochs = 3;
    cfg.sample = 1e-3;
    cfg.lr = 0.05;
    let model = SharedModel::init(vocab.len(), cfg.dim, cfg.seed);

    // 3. Train.
    let out = train::train(&cfg, &corpus, &vocab, &model)?;
    println!(
        "trained {} words in {:.1}s = {} words/sec",
        out.snapshot.words,
        out.snapshot.secs,
        si(out.snapshot.words_per_sec())
    );

    // 4. Evaluate against the generator's ground truth.
    let sim_set = eval::gen_similarity_set(&latent, 200, 7);
    let report = eval::eval_similarity(&sim_set, &vocab, model.m_in());
    println!(
        "similarity: Spearman rho x100 = {:.1} over {} pairs",
        report.rho100, report.pairs_covered
    );

    // 5. Nearest neighbours of a frequent word.
    let probe = vocab.word(10).to_string();
    let probe_row = model.m_in().unit_row(10);
    let mut scored: Vec<(f64, u32)> = (0..vocab.len() as u32)
        .filter(|&w| w != 10)
        .map(|w| (cosine(&probe_row, model.m_in().row(w)), w))
        .collect();
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    println!("nearest neighbours of '{probe}':");
    for (score, w) in scored.iter().take(5) {
        println!("  {:<12} cos={score:.3}", vocab.word(*w));
    }

    std::fs::remove_file(&corpus).ok();
    Ok(())
}
